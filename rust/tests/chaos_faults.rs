//! Chaos properties of the fault-tolerant coordinator: under a seeded
//! [`FaultPlan`] mixing scripted crash/restart with probabilistic frame
//! drops and corruptions, the run must (1) keep converging as long as a
//! majority stays live, (2) be bitwise deterministic — the same plan
//! replayed gives the same trajectory, byte counts, and fault ledger —
//! and (3) be bitwise TRANSPARENT when the plan is empty: the fault
//! machinery at rest must not move a single bit of the serial-parity
//! trajectory.
//!
//! Every fault here is pinned in the config (never read from the
//! environment), so these tests mean the same thing under the CI fault
//! matrix as under a bare `cargo test`.

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::round::Quorum;
use gdsec::coordinator::scheduler::CohortPlan;
use gdsec::coordinator::transport::{DelayPlan, FaultPlan, WorkerFaults};
use gdsec::coordinator::worker::{GradProvider, NativeProvider, ProviderFactory};
use gdsec::coordinator::{CoordConfig, CoordOutcome, Coordinator, DegradePolicy};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use std::sync::Arc;
use std::time::Duration;

fn problem() -> Problem {
    Problem::logistic(synthetic::dna_like(13, 96), 3, 0.05)
}

fn cfg_for(prob: &Problem) -> GdSecConfig {
    GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.05,
        xi: Xi::Uniform(40.0),
        ..Default::default()
    }
}

fn native_factories(prob: &Problem) -> Vec<ProviderFactory> {
    prob.locals
        .iter()
        .map(|l| {
            let local = l.clone();
            Box::new(move || Box::new(NativeProvider::new(local)) as Box<dyn GradProvider>)
                as ProviderFactory
        })
        .collect()
}

/// One minority-fault storm: worker 1 crashes at round 5 and restarts at
/// round 9, worker 0 loses its round-7 reply, worker 2's round-11 reply
/// is corrupted on the link — plus seeded i.i.d. drop/corrupt noise on
/// every uplink frame. A majority (2 of 3) is live at every round.
fn storm_plan() -> FaultPlan {
    let mut workers = vec![WorkerFaults::default(); 3];
    workers[0].drop_rounds = vec![7];
    workers[1].crash_at = Some(5);
    workers[1].restart_at = Some(9);
    workers[2].corrupt_rounds = vec![11];
    FaultPlan { seed: 0xC0FFEE, drop_p: 0.03, corrupt_p: 0.03, workers }
}

#[allow(clippy::too_many_arguments)]
fn run_chaos(
    prob: &Problem,
    iters: usize,
    faults: FaultPlan,
    quorum: Quorum,
    window: usize,
    degrade: DegradePolicy,
    dead_after: u32,
) -> CoordOutcome {
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg_for(prob), iters);
    ccfg.recv_timeout = Duration::from_millis(500);
    ccfg.dead_after = dead_after;
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = prob.estimate_fstar(2000);
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = quorum;
    ccfg.delay = DelayPlan::Jitter { seed: 11, lo: 0, hi: 10 };
    ccfg.stale_window = window;
    ccfg.faults = faults;
    ccfg.degrade = degrade;
    ccfg.cohort = None; // pin: chaos plans are env-independent by contract
    ccfg.evict_after = None;
    Coordinator::spawn(ccfg, prob.d, native_factories(prob)).run()
}

#[test]
fn minority_fault_storm_still_converges() {
    // The storm under two protocol regimes: strictly synchronous, and a
    // 2-of-3 quorum with a 2-round staleness window. Either way the
    // objective must keep falling — faults cost rounds, not correctness.
    let prob = problem();
    for (label, quorum, window) in
        [("sync", Quorum::All, 1), ("quorum", Quorum::Fraction(0.6), 2)]
    {
        // dead_after = 3: the crashed worker still strikes out well
        // before its restart (strikes at rounds 5, 6, 8), while an
        // unlucky chain of random drops/corrupts cannot permanently
        // kill a live worker (a fresh reply between probes resets it).
        let out = run_chaos(
            &prob,
            60,
            storm_plan(),
            quorum,
            window,
            DegradePolicy::Freeze,
            3,
        );
        let errs = out.trace.errors();
        assert!(errs.last().unwrap().is_finite(), "[{label}] diverged");
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.5),
            "[{label}] fault storm stalled convergence: {} -> {}",
            errs[0],
            errs.last().unwrap()
        );
        // The scripted faults really fired and were really ledgered.
        let dropped: u64 = out.rounds.iter().map(|r| r.dropped_frames).sum();
        let corrupt: u64 = out.rounds.iter().map(|r| r.corrupt_frames).sum();
        let rejoined: u64 = out.rounds.iter().map(|r| r.rejoined).sum();
        assert!(dropped >= 1, "[{label}] scripted drop never fired");
        assert!(corrupt >= 1, "[{label}] scripted corruption never fired");
        assert_eq!(rejoined, 1, "[{label}] crash/restart handshake miscounted");
        // The crashed worker came back: nobody is dead at the end.
        assert!(out.dead_workers.is_empty(), "[{label}] worker 1 never re-admitted");
        assert!(out.trace.rows.iter().any(|r| r.dead >= 1), "[{label}] death never recorded");
        assert_eq!(out.trace.rows.last().unwrap().dead, 0);
    }
}

#[test]
fn same_plan_replayed_is_bitwise_deterministic() {
    // Faults are part of the experiment definition: replaying the exact
    // same seeded plan must reproduce the trajectory, the byte counts,
    // and the fault ledger bit for bit — otherwise no faulted figure is
    // reproducible. The plan has no restart: a rejoin's round depends on
    // when the worker's `Join` frame lands relative to the server's
    // drain pass (real wall-clock), which is exactly the kind of timing
    // this virtual-everything-else design quarantines — crash, drop, and
    // corrupt schedules are fully deterministic.
    let prob = problem();
    let plan = || {
        let mut workers = vec![WorkerFaults::default(); 3];
        workers[0].drop_rounds = vec![7];
        workers[1].crash_at = Some(5);
        workers[2].corrupt_rounds = vec![11];
        FaultPlan { seed: 0xC0FFEE, drop_p: 0.03, corrupt_p: 0.03, workers }
    };
    let run = || {
        run_chaos(
            &prob,
            40,
            plan(),
            Quorum::Fraction(0.6),
            2,
            DegradePolicy::Renormalize,
            2,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.trace.rows.len(), b.trace.rows.len());
    for (x, y) in a.trace.rows.iter().zip(b.trace.rows.iter()) {
        assert_eq!(x.fval.to_bits(), y.fval.to_bits(), "fval replay drift at iter {}", x.iter);
        assert_eq!(x.bits, y.bits);
        assert_eq!(x.entries, y.entries);
        assert_eq!(x.stale, y.stale);
        assert_eq!(x.dead, y.dead);
        assert_eq!(x.rejoined, y.rejoined);
        assert_eq!(x.dropped_frames, y.dropped_frames);
        assert_eq!(x.corrupt_frames, y.corrupt_frames);
    }
    assert_eq!(a.dead_workers, b.dead_workers);
    assert_eq!(a.uplink_frame_bytes, b.uplink_frame_bytes);
    assert_eq!(a.downlink_frame_bytes, b.downlink_frame_bytes);
}

#[test]
fn empty_plan_is_bitwise_transparent() {
    // With the fault plan empty and degradation at Freeze, the entire
    // fault-tolerance layer (liveness machine, h-share ledger, drain
    // pass, fold rescale) must be invisible: bitwise identical to the
    // serial reference, with an all-zero fault ledger.
    let prob = problem();
    let cfg = cfg_for(&prob);
    let iters = 50;
    let serial = gdsec::algo::gdsec::run(&prob, &cfg, iters);
    let prob2 = prob.clone();
    let mut ccfg = CoordConfig::new(cfg, iters);
    ccfg.problem_name = prob.name.clone();
    ccfg.fstar = prob.estimate_fstar(2000);
    ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
    ccfg.quorum = Quorum::All;
    ccfg.stale_window = 1;
    ccfg.faults = FaultPlan::default();
    ccfg.degrade = DegradePolicy::Freeze;
    ccfg.cohort = None; // pin: transparency is against the full-participation serial run
    ccfg.evict_after = None;
    let out = Coordinator::spawn(ccfg, prob.d, native_factories(&prob)).run();
    assert_eq!(serial.rows.len(), out.trace.rows.len());
    for (s, d) in serial.rows.iter().zip(out.trace.rows.iter()) {
        assert_eq!(s.fval.to_bits(), d.fval.to_bits(), "transparency broken at iter {}", s.iter);
        assert_eq!(s.bits, d.bits);
        assert_eq!(d.dead, 0);
        assert_eq!(d.rejoined, 0);
        assert_eq!(d.dropped_frames, 0);
        assert_eq!(d.corrupt_frames, 0);
    }
    assert!(out.dead_workers.is_empty());
}

#[test]
fn eviction_is_bitwise_transparent_under_fault_storm() {
    // Ledger eviction is a memory layout choice, never an arithmetic
    // one — even with the fault machinery firing. The same seeded cohort
    // plus a deterministic storm (crash without restart, scripted and
    // i.i.d. drops/corrupts — no restart: a rejoin's round depends on
    // real wall-clock Join timing) is run twice: once with the default
    // tight idle horizon (slabs cycle through evict → park → restore)
    // and once with a never-fires horizon (the O(M·d) always-resident
    // replica). Trajectory, byte counts, fault ledger, and dead set must
    // match bit for bit; only the residency telemetry may differ.
    let prob = problem();
    let storm = || {
        let mut workers = vec![WorkerFaults::default(); 3];
        workers[0].drop_rounds = vec![7];
        workers[1].crash_at = Some(12);
        workers[2].corrupt_rounds = vec![9];
        FaultPlan { seed: 0xBEEF, drop_p: 0.02, corrupt_p: 0.02, workers }
    };
    let run = |evict_after: Option<u32>| {
        let prob2 = prob.clone();
        let mut ccfg = CoordConfig::new(cfg_for(&prob), 40);
        ccfg.recv_timeout = Duration::from_millis(500);
        ccfg.dead_after = 2;
        ccfg.problem_name = prob.name.clone();
        ccfg.fstar = prob.estimate_fstar(2000);
        ccfg.evaluator = Some(Arc::new(move |t: &[f64]| prob2.value(t)));
        ccfg.quorum = Quorum::All;
        ccfg.delay = DelayPlan::Jitter { seed: 11, lo: 0, hi: 10 };
        ccfg.faults = storm();
        ccfg.degrade = DegradePolicy::Renormalize;
        ccfg.cohort = Some(CohortPlan::fraction(0.67, 0xE71C));
        ccfg.evict_after = evict_after;
        Coordinator::spawn(ccfg, prob.d, native_factories(&prob)).run()
    };
    let evicting = run(None); // cohort set -> default horizon (1 round)
    let replica = run(Some(u32::MAX)); // never ages out: always resident
    assert!(evicting.state_evictions > 0, "tight horizon never evicted");
    assert_eq!(replica.state_evictions, 0, "replica must never evict");
    // (No memory comparison here: at m = 3 with near-dense ledgers the
    // 12 B/entry parked images can cost more than the 8 B/coord slabs
    // they replace — the O(cohort) win is a fleet-scale, rare-feature
    // claim, asserted in the federated bench and 10k smoke.)
    assert_eq!(evicting.trace.rows.len(), replica.trace.rows.len());
    for (e, r) in evicting.trace.rows.iter().zip(replica.trace.rows.iter()) {
        assert_eq!(
            e.fval.to_bits(),
            r.fval.to_bits(),
            "eviction moved a bit at iter {}",
            e.iter
        );
        assert_eq!(e.bits, r.bits);
        assert_eq!(e.entries, r.entries);
        assert_eq!(e.dead, r.dead);
        assert_eq!(e.dropped_frames, r.dropped_frames);
        assert_eq!(e.corrupt_frames, r.corrupt_frames);
    }
    assert_eq!(evicting.dead_workers, replica.dead_workers);
    assert_eq!(evicting.uplink_frame_bytes, replica.uplink_frame_bytes);
    assert_eq!(evicting.downlink_frame_bytes, replica.downlink_frame_bytes);
}

#[test]
fn renormalize_survives_permanent_minority_crash() {
    // Renormalize: a permanently-crashed worker is fully retired — its
    // parked updates evicted, its h-share withdrawn — and the survivors'
    // aggregate is rescaled by M/live. The run keeps converging on the
    // surviving shards' objective direction, and the dead level sticks.
    let prob = problem();
    let mut workers = vec![WorkerFaults::default(); 3];
    workers[1].crash_at = Some(5);
    let plan = FaultPlan { workers, ..FaultPlan::default() };
    let out = run_chaos(&prob, 60, plan, Quorum::All, 1, DegradePolicy::Renormalize, 1);
    assert_eq!(out.dead_workers, vec![1]);
    assert_eq!(out.trace.rows.last().unwrap().dead, 1);
    assert_eq!(out.trace.rows.last().unwrap().rejoined, 0);
    let errs = out.trace.errors();
    assert!(errs.last().unwrap().is_finite());
    // f* is the full-problem optimum, which 2 of 3 shards cannot reach
    // exactly — but the error must still shrink hard from f(0).
    assert!(
        errs.last().unwrap() < &(errs[0] * 0.5),
        "renormalized survivors stalled: {} -> {}",
        errs[0],
        errs.last().unwrap()
    );
}
