//! Algorithm-level integration tests: convergence-theory checks
//! (Theorems 1–3), cross-algorithm consistency, and end-to-end behaviour
//! of the full baseline suite on shared workloads.

use gdsec::algo::engine::EngineOpts;
use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::{cgd, gd, iag, qgd, sgdsec, topj};
use gdsec::compress::WireFormat;
use gdsec::data::synthetic;
use gdsec::objectives::{ObjectiveKind, Problem};
use gdsec::util::pool::Pool;

fn logreg_problem(seed: u64) -> Problem {
    Problem::logistic(synthetic::paper_logreg(seed, 5, 50, 300), 5, 1.0 / 250.0)
}

#[test]
fn theorem1_linear_rate_strongly_convex() {
    // Under (13) with α = 1/L the error must contract geometrically:
    // stable per-iteration contraction ratio over the trajectory.
    // Well-conditioned strongly-convex problem (dna-like, λ=0.1) — the
    // paper-recipe synthetic has κ ~ 1e5 and converges too slowly to
    // resolve a rate within a test budget.
    let prob = Problem::logistic(synthetic::dna_like(1, 120), 3, 0.1);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(30.0),
        ..Default::default()
    };
    let t = gdsec_algo::run(&prob, &cfg, 800);
    let errs = t.errors();
    let e100 = errs[100];
    let e400 = errs[400];
    let e700 = errs[700];
    assert!(e400 < e100 * 0.5, "not contracting: {e100} -> {e400}");
    assert!(e700 < e400 * 0.7, "stalls: {e400} -> {e700}");
    let r1 = (e400 / e100).powf(1.0 / 300.0);
    let r2 = (e700 / e400).powf(1.0 / 300.0);
    assert!(r1 < 1.0 && r2 < 1.0);
    assert!((r1 - r2).abs() < 0.05, "rate not geometric: {r1} vs {r2}");
}

#[test]
fn theorem3_nonconvex_objective_decreases() {
    let data = synthetic::w2a_like(3, 600);
    let prob = Problem::nlls(data, 5, 1.0 / 600.0);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(2000.0 * 5.0),
        ..Default::default()
    };
    let t = gdsec_algo::run(&prob, &cfg, 400);
    // Lyapunov-style: objective decreases overall; tiny oscillations are
    // tolerated (the Lyapunov function, not f itself, is monotone).
    let f0 = t.rows[0].fval;
    let fend = t.rows.last().unwrap().fval;
    assert!(fend < f0, "{f0} -> {fend}");
    let worst_bump = t
        .rows
        .windows(2)
        .map(|w| w[1].fval - w[0].fval)
        .fold(f64::NEG_INFINITY, f64::max);
    assert!(worst_bump < (f0 - fend) * 0.05, "large non-monotonicity {worst_bump}");
}

#[test]
fn gdsec_beats_every_baseline_on_bits_paper_fig2_setup() {
    // Well-conditioned logistic problem so all algorithms reach a tight
    // common target within the test budget; at tight targets GD-SEC's
    // adaptive censoring dominates every baseline (paper Figs 1-2).
    let prob = Problem::logistic(synthetic::dna_like(7, 240), 4, 0.05);
    let alpha = 1.0 / prob.lipschitz();
    let lambda = prob.lambda;
    let iters = 600;
    let fstar = prob.estimate_fstar(4000);
    let t_gd =
        gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    let t_sec = gdsec_algo::run(
        &prob,
        &GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::Uniform(200.0),
            fstar: Some(fstar),
            ..Default::default()
        },
        iters,
    );
    let t_cgd = cgd::run(
        &prob,
        &cgd::CgdConfig { alpha, xi: 4.0, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_qgd = qgd::run(
        &prob,
        &qgd::QgdConfig { alpha, s: 255, seed: 1, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    let t_topj = topj::run(
        &prob,
        &topj::TopJConfig { j: 10, gamma0: 0.01, lambda, eval_every: 1, fstar: Some(fstar) },
        iters,
    );
    // target: what both GD and GD-SEC comfortably reach
    let eps = t_gd.final_error().max(t_sec.final_error()) * 3.0;
    let sec_bits = t_sec.bits_to_reach(eps).expect("GD-SEC must reach eps");
    for other in [&t_gd, &t_cgd, &t_qgd, &t_topj] {
        if let Some(b) = other.bits_to_reach(eps) {
            assert!(
                sec_bits < b,
                "GD-SEC ({sec_bits}) not cheaper than {} ({b}) at eps {eps:.2e}",
                other.algo
            );
        } // baseline never reaching the target counts as a GD-SEC win
    }
}

#[test]
fn all_objectives_converge_under_gdsec() {
    for kind in
        [ObjectiveKind::LinReg, ObjectiveKind::LogReg, ObjectiveKind::Lasso, ObjectiveKind::Nlls]
    {
        let prob = Problem::new(kind, synthetic::dna_like(11, 300), 4, 0.02);
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.01,
            xi: Xi::Uniform(50.0),
            ..Default::default()
        };
        let t = gdsec_algo::run(&prob, &cfg, 250);
        let errs = t.errors();
        assert!(
            errs.last().unwrap() < &(errs[0] * 0.3),
            "{kind:?}: {} -> {}",
            errs[0],
            errs.last().unwrap()
        );
    }
}

#[test]
fn iag_and_stochastic_paths_run_on_shared_problem() {
    let prob = logreg_problem(13);
    let alpha = 1.0 / prob.lipschitz();
    let t_iag = iag::run(
        &prob,
        &iag::IagConfig { alpha: alpha / 10.0, seed: 5, eval_every: 2, fstar: None },
        200,
    );
    assert!(t_iag.final_error().is_finite());
    let scfg = sgdsec::SgdSecConfig {
        gamma0: 0.01,
        lambda: prob.lambda,
        beta: 0.01,
        xi: Xi::Uniform(400.0),
        batch: 5,
        seed: 5,
        quantize_s: None,
        eval_every: 5,
        fstar: None,
    };
    let t_sec = sgdsec::run_sgdsec(&prob, &scfg, 200);
    let t_sgd = sgdsec::run_sgd(&prob, &scfg, 200);
    assert!(t_sec.total_bits() < t_sgd.total_bits());
}

#[test]
fn adaptive_wire_accounting_caps_dense_first_round() {
    // The single-process trainers' bit accounting knows the adaptive
    // tag-byte option (the crate default): trajectories are identical to
    // the sparse accounting — only the charged bits differ — and the
    // dense first round (θ^1 = θ^0 ⇒ zero thresholds ⇒ everything
    // transmits) gets CHEAPER, capped at 8 + 32·d bits per transmission
    // instead of the costlier RLE stream. Continuous (mnist-like)
    // features: every first-round gradient component is nonzero, so the
    // first frames are genuinely dense.
    let prob = Problem::linear(synthetic::mnist_like(29, 120), 3, 0.05);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(40.0),
        fstar: Some(0.0),
        ..Default::default()
    };
    let run_wire = |wire: WireFormat| {
        let opts = EngineOpts { wire, ..EngineOpts::from_env() };
        gdsec_algo::run_states_opts(&prob, &cfg, 30, |_k| None, Pool::global(), &opts).trace
    };
    let sparse = run_wire(WireFormat::Sparse);
    let adaptive = run_wire(WireFormat::Adaptive);
    assert_eq!(sparse.rows.len(), adaptive.rows.len());
    for (s, a) in sparse.rows.iter().zip(adaptive.rows.iter()) {
        assert_eq!(
            s.fval.to_bits(),
            a.fval.to_bits(),
            "accounting format changed the trajectory at iter {}",
            s.iter
        );
        assert_eq!(s.transmissions, a.transmissions);
        assert_eq!(s.entries, a.entries);
    }
    // First-round frames are dense: every worker pays exactly the
    // adaptive cap, strictly below the sparse cost.
    let m = prob.m() as u64;
    let cap = m * (8 + 32 * prob.d as u64);
    assert_eq!(adaptive.rows[1].bits, cap, "first round not dense-capped");
    assert!(
        adaptive.rows[1].bits < sparse.rows[1].bits,
        "adaptive did not make the dense first round cheaper: {} vs {}",
        adaptive.rows[1].bits,
        sparse.rows[1].bits
    );
    // Never more than one tag byte per transmission over sparse.
    assert!(
        adaptive.total_bits() <= sparse.total_bits() + 8 * adaptive.total_transmissions()
    );
}

#[test]
fn eval_every_subsamples_trace() {
    let prob = logreg_problem(17);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        eval_every: 10,
        xi: Xi::Uniform(100.0),
        ..Default::default()
    };
    let t = gdsec_algo::run(&prob, &cfg, 100);
    // rows: iter 0 + every 10th
    assert_eq!(t.rows.len(), 11);
    assert_eq!(t.rows[1].iter, 10);
    assert_eq!(t.rows.last().unwrap().iter, 100);
}

#[test]
fn more_workers_than_samples() {
    // Some shards are empty; nothing panics and empty-shard workers
    // contribute only the regularizer gradient.
    let prob = Problem::linear(synthetic::dna_like(19, 5), 8, 0.1);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz().max(1e-9),
        xi: Xi::Uniform(1.0),
        ..Default::default()
    };
    let t = gdsec_algo::run(&prob, &cfg, 30);
    assert!(t.final_error().is_finite());
}

#[test]
fn diverging_run_keeps_bit_accounting_sane() {
    // An absurd step size diverges numerically, but the bit counters must
    // stay monotone and finite.
    let prob = logreg_problem(23);
    let cfg =
        GdSecConfig { alpha: 1e6, beta: 1.0, xi: Xi::Uniform(0.0), ..Default::default() };
    let t = gdsec_algo::run(&prob, &cfg, 20);
    let mut prev = 0;
    for r in &t.rows {
        assert!(r.bits >= prev);
        prev = r.bits;
    }
}
