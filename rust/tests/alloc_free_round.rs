//! Pins the zero-allocation steady-state round invariant: once lane
//! buffers have warmed up, a full GD-SEC optimizer round — θ-diff,
//! per-worker gradient + sparsify into reused buffers, fused server
//! apply — performs NO heap allocation. This holds on the serial path
//! AND through the persistent pool: a `Pool::scatter` round is a
//! stack-held context dispatched to parked workers over a futex-based
//! mutex/condvar pair, so no spawns, boxes, or channel nodes exist on
//! the per-round path.
//!
//! A counting global allocator wraps `System` (counting allocations from
//! EVERY thread, pool workers included); this file contains exactly one
//! test so no concurrent harness activity can pollute the counter.

use gdsec::algo::engine::{Engine, EngineOpts};
use gdsec::algo::gdsec::{GdSecConfig, GdSecRule, ServerState, WorkerState, Xi};
use gdsec::compress::SparseUpdate;
use gdsec::coordinator::round::{split_due, StaleUpdate};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::pool::Pool;
use gdsec::util::shard::{ShardApply, ShardPlan, ShareBook};
use gdsec::util::state_store::StateStore;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_round_allocates_nothing() {
    let prob = Problem::linear(synthetic::dna_like(5, 120), 3, 0.01);
    let d = prob.d;
    let m = prob.m();
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(60.0),
        ..Default::default()
    };
    let mut server = ServerState::new(d);
    let mut lanes: Vec<(WorkerState, SparseUpdate)> =
        (0..m).map(|_| (WorkerState::new(d), SparseUpdate::empty(d))).collect();
    let mut theta_diff = vec![0.0; d];

    // Exactly the round body run_states executes per iteration (inline,
    // thread count 1).
    let mut round = |server: &mut ServerState,
                     lanes: &mut Vec<(WorkerState, SparseUpdate)>,
                     theta_diff: &mut Vec<f64>| {
        server.theta_diff(theta_diff);
        for (w, (ws, up)) in lanes.iter_mut().enumerate() {
            prob.locals[w].grad(&server.theta, ws.grad_mut());
            ws.sparsify_into(&cfg, m, theta_diff, up);
        }
        server.apply_round(&cfg, lanes.iter().filter(|(_, up)| up.nnz() > 0).map(|(_, up)| up));
    };

    // Warm-up: round 1 transmits every component (θ-diff is zero), so the
    // lane buffers reach their maximum capacity immediately.
    for _ in 0..3 {
        round(&mut server, &mut lanes, &mut theta_diff);
    }

    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        round(&mut server, &mut lanes, &mut theta_diff);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state GD-SEC rounds performed heap allocations"
    );
    // Sanity: the run actually optimized (not a no-op loop).
    assert!(server.theta.iter().any(|&t| t != 0.0));

    // --- Persistent-pool phase: the same round body fanned over a
    //     3-thread pool must also be allocation-free once the pool
    //     exists (thread spawn happens HERE, before the counter). ---
    let pool = Pool::new(3);
    let mut pooled_round = |server: &mut ServerState,
                            lanes: &mut Vec<(WorkerState, SparseUpdate)>,
                            theta_diff: &mut Vec<f64>| {
        server.theta_diff(theta_diff);
        {
            let theta: &[f64] = &server.theta;
            let diff: &[f64] = theta_diff;
            pool.scatter(lanes, |w, lane| {
                let (ws, up) = lane;
                prob.locals[w].grad(theta, ws.grad_mut());
                ws.sparsify_into(&cfg, m, diff, up);
            });
        }
        server.apply_round(&cfg, lanes.iter().filter(|(_, up)| up.nnz() > 0).map(|(_, up)| up));
    };
    for _ in 0..3 {
        pooled_round(&mut server, &mut lanes, &mut theta_diff);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        pooled_round(&mut server, &mut lanes, &mut theta_diff);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state pooled GD-SEC rounds performed heap allocations"
    );

    // --- Pinned-pool phase: the same scatter round over a pool whose
    //     helpers pinned themselves to cores at spawn (the
    //     `GDSEC_PIN_CORES` path, forced on here) must stay
    //     allocation-free: pinning is a one-shot sched_setaffinity with
    //     a stack-held CPU mask inside the helper before its first
    //     park, so the steady-state round path is byte-for-byte the
    //     unpinned one. ---
    let pinned = Pool::with_affinity(3, true);
    let mut pinned_round = |server: &mut ServerState,
                            lanes: &mut Vec<(WorkerState, SparseUpdate)>,
                            theta_diff: &mut Vec<f64>| {
        server.theta_diff(theta_diff);
        {
            let theta: &[f64] = &server.theta;
            let diff: &[f64] = theta_diff;
            pinned.scatter(lanes, |w, lane| {
                let (ws, up) = lane;
                prob.locals[w].grad(theta, ws.grad_mut());
                ws.sparsify_into(&cfg, m, diff, up);
            });
        }
        server.apply_round(&cfg, lanes.iter().filter(|(_, up)| up.nnz() > 0).map(|(_, up)| up));
    };
    for _ in 0..3 {
        pinned_round(&mut server, &mut lanes, &mut theta_diff);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        pinned_round(&mut server, &mut lanes, &mut theta_diff);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state pinned-pool GD-SEC rounds performed heap allocations"
    );

    // --- Unified-engine phase: the REAL `Engine::step` round (nested
    //     (worker, row-block) lanes forced multi-block, pooled fan-out,
    //     full-participation schedule) must also be allocation-free once
    //     the engine's buffers are built. ---
    let opts = EngineOpts { nnz_budget: 256, stale_window: 3, ..EngineOpts::default() };
    let mut eng = Engine::new(&prob, GdSecRule::new(cfg.clone()), &pool, &opts, 0.0);
    for _ in 0..3 {
        eng.step(None);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        eng.step(None);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state engine rounds performed heap allocations"
    );
    assert!(eng.iter() == 28 && eng.server.theta.iter().any(|&t| t != 0.0));

    // --- Quorum/stale-fold phase: semi-synchronous rounds where one
    //     worker is late every round — its transmission parked by the
    //     cut and folded one round later via `CompressRule::fold_stale`
    //     (staged into the server scratch) — must be allocation-free
    //     too: the stale path reuses the lane's wire buffer and the
    //     pre-sized aggregation scratch. ---
    const LATE: [usize; 1] = [1];
    for _ in 0..3 {
        eng.step_quorum(None, Some(&LATE));
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        eng.step_quorum(None, Some(&LATE));
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state quorum (stale-fold) engine rounds performed heap allocations"
    );
    assert!(eng.iter() == 56);

    // --- Multi-round staleness window: the aged quorum path (worker 1's
    //     transmission spends 2 rounds in flight — it sits out a round,
    //     then `fold_stale` fires at age 2) must also be allocation-free:
    //     the in-flight bookkeeping is two pre-sized index vectors and
    //     the fold scans a fixed (origin round, worker) grid. ---
    const LATE_AGED: [(usize, u32); 1] = [(1, 2)];
    for _ in 0..4 {
        eng.step_quorum_aged(None, Some(&LATE_AGED));
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..24 {
        eng.step_quorum_aged(None, Some(&LATE_AGED));
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state aged-quorum (staleness window) engine rounds performed heap allocations"
    );
    assert!(eng.iter() == 84);

    // --- Sharded-coordinator phase: the coordinator's threaded
    //     aggregation round — due-split of the stale pool
    //     (`split_due`: unstable in-place sort + swap compaction into a
    //     warm caller-owned buffer), then the persistent `ShardPlan`
    //     fold (per-update shard cuts, agg + fold_scale rescale + θ/h
    //     step + per-worker h-share booking) fanned over the 3-thread
    //     pool — must be allocation-free at steady state: the plan's
    //     slot/cut/pointer tables and the due/stale vectors all reuse
    //     their capacity. Each round the due entries are recycled back
    //     into the stale pool (re-dated one round ahead) so the
    //     stale-fold path stays exercised every measured round. ---
    let mut plan = ShardPlan::new();
    let mut theta = vec![0.1f64; d];
    let mut h = vec![0.0f64; d];
    let mut agg = vec![0.0f64; d];
    // Ledgers live in the always-resident state store: bit-for-bit and
    // allocation-for-allocation the old dense `Vec<Vec<f64>>` (identity
    // slot map, staging/eviction no-ops).
    let mut store = StateStore::resident(d, m);
    let fresh: Vec<Option<SparseUpdate>> = (0..m)
        .map(|w| {
            let mut u = SparseUpdate::empty(d);
            for i in 0..8u32 {
                u.idx.push(w as u32 + i * m as u32);
                u.val.push(1e-4);
            }
            Some(u)
        })
        .collect();
    let mut stale_pool: Vec<StaleUpdate> = (0..m)
        .map(|w| {
            let mut u = SparseUpdate::empty(d);
            u.idx.push(100 + w as u32);
            u.val.push(1e-4);
            StaleUpdate { round: 3, worker: w, age: 1, update: u }
        })
        .collect();
    let mut due: Vec<StaleUpdate> = Vec::new();
    let beta = cfg.beta;
    let mut coord_round = |k: usize| {
        split_due(&mut stale_pool, k, &mut due);
        assert_eq!(due.len(), m, "recycled stale entries must all come due");
        let (slabs, slot_of) = store.book_view();
        plan.fold(
            &pool,
            due.iter()
                .map(|s| (s.worker, &s.update))
                .chain(fresh.iter().enumerate().filter_map(|(w, u)| u.as_ref().map(|u| (w, u)))),
            ShardApply {
                theta: &mut theta,
                h: &mut h,
                agg: &mut agg,
                theta_prev: None,
                alpha: 0.01,
                beta,
                state_variable: true,
                fold_scale: 1.0,
                staged_agg: false,
                shares: Some(ShareBook { slabs, slot_of, scale: beta }),
            },
        );
        // Recycle: the folded entries go back into the pool, due again
        // next round — swap-moves of warm buffers, no allocation.
        for mut s in due.drain(..) {
            s.round = k as u32;
            s.age = 1;
            stale_pool.push(s);
        }
    };
    for k in 0..3 {
        coord_round(4 + k);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for k in 0..25 {
        coord_round(7 + k);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state sharded coordinator rounds performed heap allocations"
    );
    // Sanity: the fold actually moved the model and booked the ledger.
    assert!(theta.iter().any(|&t| t != 0.1));
    {
        let (slabs, slot_of) = store.book_view();
        assert!(slot_of.is_none(), "resident store must book through the identity map");
        assert!(slabs.iter().all(|s| s.iter().any(|&v| v != 0.0)));
    }

    // --- Evictable state-store phase: cohort rounds with the default
    //     idle horizon — each round evicts the previous half-cohort's
    //     ledgers (O(touched) compaction into parked buffers) and
    //     re-admits the returning half (free-list slab + bitwise
    //     rehydration + touched-list merge through the shared scratch).
    //     With alternating half-cohorts every ledger makes a full
    //     evict → restore round-trip every two rounds; once the parked
    //     buffers, free list, and scratch are warm, the whole cycle must
    //     be allocation-free. ---
    let mut estore = StateStore::evicting(d, m, 1);
    let mut etheta = vec![0.1f64; d];
    let mut eh = vec![0.0f64; d];
    let mut eagg = vec![0.0f64; d];
    let mut eplan = ShardPlan::new();
    let mut store_round = |k: u32, estore: &mut StateStore| {
        estore.evict_idle(k);
        let par = (k % 2) as usize;
        for (w, u) in fresh.iter().enumerate() {
            if w % 2 == par {
                if let Some(u) = u {
                    estore.stage(w, k, &u.idx);
                }
            }
        }
        let (slabs, slot_of) = estore.book_view();
        eplan.fold(
            &pool,
            fresh
                .iter()
                .enumerate()
                .filter(|(w, _)| w % 2 == par)
                .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
            ShardApply {
                theta: &mut etheta,
                h: &mut eh,
                agg: &mut eagg,
                theta_prev: None,
                alpha: 0.01,
                beta,
                state_variable: true,
                fold_scale: 1.0,
                staged_agg: false,
                shares: Some(ShareBook { slabs, slot_of, scale: beta }),
            },
        );
    };
    for k in 1..=4u32 {
        store_round(k, &mut estore);
    }
    let warm_evictions = estore.evictions();
    assert!(warm_evictions > 0, "alternating cohorts never evicted during warm-up");
    assert!(estore.restores() > 0, "no ledger ever rehydrated during warm-up");
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for k in 5..=28u32 {
        store_round(k, &mut estore);
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state evict/restore ledger rounds performed heap allocations"
    );
    assert!(estore.evictions() > warm_evictions, "measured rounds stopped evicting");

    // --- Virtual-transport receive phase: the server gather loop's
    //     `recv_into` seam on the in-memory transport must be
    //     allocation-free at steady state — the frame lands in the
    //     caller's warm buffer (clear + extend into existing capacity),
    //     and popping the channel node / dropping the sender-allocated
    //     frame Vec are deallocations, which the counter ignores by
    //     design. The frames are queued before the counter starts
    //     (sending allocates channel nodes; receiving must not). ---
    use gdsec::coordinator::protocol::{self, Msg};
    use gdsec::coordinator::transport::{duplex, RecvStatus, Transport};
    let (mut server_end, mut worker_end) = duplex();
    let frame = protocol::encode(&Msg::Silence { round: 1, worker: 0, local_f: 0.5 }, d as u32);
    for _ in 0..30 {
        assert!(worker_end.send(frame.clone()));
    }
    let mut rbuf: Vec<u8> = Vec::new();
    for _ in 0..3 {
        assert_eq!(
            server_end.recv_into(&mut rbuf, std::time::Duration::from_secs(1)),
            RecvStatus::Frame
        );
        assert_eq!(rbuf, frame);
    }
    let before = ALLOC_CALLS.load(Ordering::SeqCst);
    for _ in 0..25 {
        assert_eq!(
            server_end.recv_into(&mut rbuf, std::time::Duration::from_secs(1)),
            RecvStatus::Frame
        );
    }
    let after = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state virtual-transport recv_into performed heap allocations"
    );
    assert_eq!(rbuf, frame);
}
