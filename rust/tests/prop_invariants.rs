//! Property-based invariants (in-tree mini-framework, `gdsec::testing`):
//! codec roundtrips, sparsifier identities, server/worker state mirrors,
//! and scheduler fairness — the coordinator invariants of DESIGN.md §8.

use gdsec::algo::gdsec::{GdSecConfig, ServerState, WorkerState, Xi};
use gdsec::compress::{self, quantize, rle, SparseUpdate};
use gdsec::coordinator::protocol::{self, Msg};
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::data::{synthetic, Features};
use gdsec::testing::{check, gen};
use gdsec::util::rng::Pcg64;

#[test]
fn prop_staleness_window_is_a_hard_bound() {
    // For ANY quorum policy, delay plan, and window S, an engine run
    // driven by the QuorumSim must never fold an update older than S
    // rounds: every entry of the trace's staleness-age histogram beyond
    // bin S stays zero, and the bins sum to the stale total. (The
    // histogram is fed by the same fold loop that stages the updates, so
    // pinning it pins the folds.)
    use gdsec::algo::engine::{Engine, EngineOpts};
    use gdsec::algo::gdsec::GdSecRule;
    use gdsec::coordinator::round::Quorum;
    use gdsec::coordinator::scheduler::QuorumSim;
    use gdsec::coordinator::transport::DelayPlan;
    use gdsec::objectives::Problem;
    use gdsec::util::pool::Pool;
    check("staleness window hard bound", |rng| {
        let m = 3 + rng.index(4); // 3..=6 workers
        let prob = Problem::linear(synthetic::dna_like(rng.next_u64(), 40), m, 0.1);
        let window = 1 + rng.index(3); // S ∈ {1, 2, 3}
        let quorum = match rng.index(3) {
            0 => Quorum::Count(1 + rng.index(m)),
            1 => Quorum::Fraction(0.2 + rng.uniform() * 0.7),
            _ => Quorum::Adaptive {
                target_quantile: 0.3 + rng.uniform() * 0.6,
                min_frac: 0.2 + rng.uniform() * 0.3,
            },
        };
        let plan = match rng.index(3) {
            0 => DelayPlan::PerWorker((0..m).map(|_| rng.below(500)).collect()),
            1 => DelayPlan::Jitter { seed: rng.next_u64(), lo: 0, hi: 1 + rng.below(300) },
            _ => DelayPlan::None,
        };
        let cfg = GdSecConfig {
            alpha: 1.0 / prob.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(rng.uniform() * 50.0),
            fstar: Some(0.0),
            ..Default::default()
        };
        let pool = Pool::new(1);
        let opts = EngineOpts { stale_window: window, ..EngineOpts::default() };
        let mut sim = QuorumSim::new(m, quorum, plan, window);
        let mut eng = Engine::new(&prob, GdSecRule::new(cfg), &pool, &opts, 0.0);
        for k in 1..=25 {
            let (late, _units) = sim.round(k, None);
            for &(_, age) in late {
                if age < 1 || age as usize > window {
                    return Err(format!("sim produced age {age} outside [1, {window}]"));
                }
            }
            eng.step_quorum_aged(None, Some(late));
        }
        eng.record();
        let run = eng.into_run();
        let last = run.trace.rows.last().unwrap();
        if last.stale_ages.iter().skip(window).any(|&c| c > 0) {
            return Err(format!(
                "fold with age > S={window}: histogram {:?} (quorum {quorum:?})",
                last.stale_ages
            ));
        }
        if last.stale_ages.iter().sum::<u64>() != last.stale {
            return Err("age histogram does not sum to the stale total".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rle_gap_roundtrip_arbitrary_index_sets() {
    check("rle roundtrip", |rng| {
        let n = 1 + rng.index(500);
        let mut idx: Vec<u32> = (0..n).map(|_| rng.below(1 << 22) as u32).collect();
        idx.sort_unstable();
        idx.dedup();
        let mut buf = Vec::new();
        rle::encode_gaps(&idx, &mut buf);
        if buf.len() * 8 != rle::gap_bits(&idx) {
            return Err("gap_bits != encoded length".into());
        }
        let mut back = Vec::new();
        let used =
            rle::decode_gaps(&buf, idx.len(), &mut back).ok_or("decode failed")?;
        if used != buf.len() || back != idx {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_codec_roundtrip_mixed_values() {
    check("sparse codec roundtrip", |rng| {
        let d = gen::len(rng, 3000);
        let v = gen::vec_sparse(rng, d, 0.7);
        let u = SparseUpdate::from_dense(&v);
        let mut buf = Vec::new();
        compress::encode_sparse(&u, &mut buf);
        if buf.len() * 8 != compress::sparse_bits(&u) {
            return Err("bit accounting mismatch".into());
        }
        let (back, used) = compress::decode_sparse(&buf, d as u32).ok_or("decode")?;
        if used != buf.len() || back != u {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_roundtrip_and_level_bounds() {
    check("qsgd roundtrip", |rng| {
        let d = gen::len(rng, 800);
        let v = gen::vec_mixed(rng, d);
        let s = 1 + rng.index(255) as u8;
        let q = quantize::quantize(&v, s, rng);
        if q.levels.iter().any(|&l| l == 0 || l.unsigned_abs() > s as u16) {
            return Err("level out of bounds".into());
        }
        let mut buf = Vec::new();
        quantize::encode(&q, &mut buf);
        let (back, used) = quantize::decode(&buf, d as u32).ok_or("decode")?;
        if used != buf.len() || back != q {
            return Err("roundtrip mismatch".into());
        }
        // dequantized magnitudes bounded by the norm
        let dq = quantize::dequantize(&q);
        let norm = q.norm as f64;
        if dq.iter().any(|x| x.abs() > norm * (1.0 + 1e-5)) {
            return Err("dequantized value exceeds norm".into());
        }
        Ok(())
    });
}

#[test]
fn prop_sparsify_ec_identity_and_threshold() {
    // For every coordinate: wire + e_new == delta exactly; suppressed
    // coords satisfy |delta| <= tau; transmitted coords satisfy
    // |delta| > tau; h moves only on transmitted coords (by beta*wire).
    check("sparsify invariants", |rng| {
        let d = gen::len(rng, 600);
        let m = 1 + rng.index(10);
        let mut ws = WorkerState::new(d);
        for i in 0..d {
            ws.h[i] = rng.normal() * 0.1;
            ws.e[i] = rng.normal() * 0.05;
        }
        let h_before = ws.h.clone();
        let e_before = ws.e.clone();
        let grad = gen::vec_mixed(rng, d);
        ws.grad_mut().copy_from_slice(&grad);
        let diff = gen::vec_mixed(rng, d);
        let xi_val = rng.uniform_in(0.0, 200.0);
        let cfg = GdSecConfig {
            beta: rng.uniform_in(0.0, 1.0),
            xi: Xi::Uniform(xi_val),
            ..Default::default()
        };
        let up = ws.sparsify_step(&cfg, m, &diff);
        let dense = up.to_dense();
        for i in 0..d {
            let delta = grad[i] - h_before[i] + e_before[i];
            let tau = xi_val / m as f64 * diff[i].abs();
            let transmitted = dense[i] != 0.0 || (delta.abs() > tau && delta as f32 == 0.0);
            if delta.abs() > tau && !transmitted {
                return Err(format!("coord {i}: should transmit (|Δ|={} > τ={tau})", delta.abs()));
            }
            if delta.abs() <= tau && dense[i] != 0.0 {
                return Err(format!("coord {i}: censored coord on wire"));
            }
            // EC identity
            if (dense[i] + ws.e[i] - delta).abs() > 1e-12 {
                return Err(format!("coord {i}: EC identity broken"));
            }
            // h update rule
            let expect_h = h_before[i] + cfg.beta * dense[i];
            if (ws.h[i] - expect_h).abs() > 1e-12 {
                return Err(format!("coord {i}: h update wrong"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_server_h_mirrors_worker_h_sum() {
    // After arbitrary censor patterns over several rounds, the server's
    // state variable equals the sum of worker state variables exactly
    // (both integrate beta * the same wire values).
    check("h mirror", |rng| {
        let d = 1 + rng.index(200);
        let m = 1 + rng.index(6);
        let rounds = 1 + rng.index(10);
        let cfg = GdSecConfig {
            alpha: 0.001,
            beta: rng.uniform_in(0.01, 1.0),
            xi: Xi::Uniform(rng.uniform_in(0.0, 50.0)),
            ..Default::default()
        };
        let mut server = ServerState::new(d);
        let mut workers: Vec<WorkerState> = (0..m).map(|_| WorkerState::new(d)).collect();
        let mut diff = vec![0.0; d];
        for _round in 0..rounds {
            server.theta_diff(&mut diff);
            let mut ups = Vec::new();
            for ws in workers.iter_mut() {
                let g = gen::vec_mixed(rng, d);
                ws.grad_mut().copy_from_slice(&g);
                let up = ws.sparsify_step(&cfg, m, &diff);
                if up.nnz() > 0 {
                    ups.push(up);
                }
            }
            server.apply_round(&cfg, &ups);
            for i in 0..d {
                let sum_h: f64 = workers.iter().map(|w| w.h[i]).sum();
                if (server.h[i] - sum_h).abs() > 1e-9 * sum_h.abs().max(1.0) {
                    return Err(format!(
                        "mirror broken at coord {i}: server {} vs sum {sum_h}",
                        server.h[i]
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_split_rows_by_nnz_partitions_within_budget() {
    // The engine's nested-lane cut: blocks partition [0, rows) exactly,
    // in order, and no block exceeds the nnz budget unless it is a
    // single row whose own nnz already does (never overshoots by more
    // than that one row).
    check("split_rows_by_nnz invariants", |rng| {
        let rows = rng.index(80);
        let d = 30 + rng.index(300);
        let avg_nnz = 1 + rng.index(20);
        let ds = synthetic::rcv1_like(rng.next_u64(), rows, d, avg_nnz);
        let Features::Sparse(a) = &ds.x else {
            return Err("rcv1_like must be sparse".to_string());
        };
        let budget = 1 + rng.index(4 * avg_nnz.max(1) * 8);
        let blocks = a.split_rows_by_nnz(budget);
        // Exact, ordered partition.
        let mut cursor = 0usize;
        for &(s, e) in &blocks {
            if s != cursor || e <= s {
                return Err(format!("blocks not an ordered partition at ({s}, {e})"));
            }
            cursor = e;
        }
        if cursor != a.rows {
            return Err(format!("blocks cover {cursor} of {} rows", a.rows));
        }
        // Budget respected except for single over-budget rows.
        for &(s, e) in &blocks {
            let nnz = a.indptr[e] - a.indptr[s];
            if nnz > budget && e - s != 1 {
                return Err(format!("block {s}..{e} has nnz {nnz} > budget {budget}"));
            }
        }
        // Greedy maximality: a block that ends before the last row could
        // not have absorbed the next row without busting the budget.
        for &(s, e) in &blocks {
            if e < a.rows {
                let with_next = a.indptr[e + 1] - a.indptr[s];
                if with_next <= budget {
                    return Err(format!(
                        "block {s}..{e} should have absorbed row {e} ({with_next} <= {budget})"
                    ));
                }
            }
        }
        // The Features wrapper agrees with the CSR cut.
        if ds.x.split_rows_by_nnz(budget) != blocks {
            return Err("Features::split_rows_by_nnz disagrees with CsrMat".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scheduler_rr_covers_all_workers() {
    check("rr coverage", |rng| {
        let m = 2 + rng.index(40);
        let fraction = rng.uniform_in(0.05, 1.0);
        let mut s = Scheduler::RoundRobin { fraction };
        let c = s.active_count(m);
        let mut seen = vec![false; m];
        // one full cycle is ceil(m/c) rounds; run 2 cycles
        let rounds = 2 * m.div_ceil(c);
        for k in 1..=rounds {
            for w in s.active(k, m) {
                if w >= m {
                    return Err("worker out of range".into());
                }
                seen[w] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err(format!("not all workers scheduled in {rounds} rounds (c={c})"));
        }
        Ok(())
    });
}

#[test]
fn prop_protocol_frames_roundtrip() {
    check("protocol roundtrip", |rng| {
        let d = gen::len(rng, 1000) as u32;
        let msg = match rng.index(4) {
            0 => Msg::Broadcast {
                round: rng.below(1 << 30) as u32,
                theta: gen::vec_mixed(rng, d as usize),
                active: rng.bernoulli(0.5),
            },
            1 => {
                let v = gen::vec_sparse(rng, d as usize, 0.8);
                Msg::Update {
                    round: rng.below(1 << 30) as u32,
                    worker: rng.below(1000) as u32,
                    update: SparseUpdate::from_dense(&v),
                    local_f: rng.normal(),
                }
            }
            2 => Msg::Silence {
                round: rng.below(1 << 30) as u32,
                worker: rng.below(1000) as u32,
                local_f: rng.normal(),
            },
            _ => Msg::Shutdown,
        };
        let buf = protocol::encode(&msg, d);
        let back = protocol::decode(&buf, d).map_err(|e| e.to_string())?;
        if back != msg {
            return Err("frame roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_protocol_rejects_random_corruption() {
    check("protocol corruption", |rng| {
        let v = gen::vec_sparse(rng, 64, 0.5);
        let msg = Msg::Update {
            round: 1,
            worker: 0,
            update: SparseUpdate::from_dense(&v),
            local_f: 0.5,
        };
        let mut buf = protocol::encode(&msg, 64);
        // Either truncate or flip the magic/kind byte — must error or
        // decode to *something* (never panic); flipped payload bytes may
        // still parse (values change), which is fine.
        match rng.index(3) {
            0 => {
                let cut = rng.index(buf.len());
                if protocol::decode(&buf[..cut], 64).is_ok() {
                    return Err("truncated frame decoded".into());
                }
            }
            1 => {
                buf[0] ^= 0xff;
                if protocol::decode(&buf, 64).is_ok() {
                    return Err("bad magic decoded".into());
                }
            }
            _ => {
                buf[1] = 200;
                if protocol::decode(&buf, 64).is_ok() {
                    return Err("bad kind decoded".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topj_keeps_exactly_j_largest() {
    check("topj selection", |rng| {
        let d = gen::len(rng, 400);
        let j = rng.index(d + 1);
        let v = gen::vec_mixed(rng, d);
        let idx = compress::topj::top_j_indices(&v, j);
        if idx.len() != j.min(d) {
            return Err("wrong count".into());
        }
        let kept_min = idx.iter().map(|&i| v[i as usize].abs()).fold(f64::INFINITY, f64::min);
        let dropped_max = (0..d as u32)
            .filter(|i| !idx.contains(i))
            .map(|i| v[i as usize].abs())
            .fold(0.0f64, f64::max);
        if j > 0 && j < d && kept_min + 1e-15 < dropped_max {
            return Err(format!("kept {kept_min} < dropped {dropped_max}"));
        }
        Ok(())
    });
}

#[test]
fn prop_quantizer_unbiased_mean() {
    // Coarse unbiasedness over repeated draws for a random small vector.
    let mut outer = Pcg64::seeded(0xBEEF);
    for _case in 0..5 {
        let d = 1 + outer.index(8);
        let v: Vec<f64> = (0..d).map(|_| outer.normal()).collect();
        let trials = 4000;
        let mut acc = vec![0.0; d];
        for _ in 0..trials {
            let q = quantize::quantize(&v, 8, &mut outer);
            for (a, x) in acc.iter_mut().zip(quantize::dequantize(&q)) {
                *a += x;
            }
        }
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        for i in 0..d {
            let mean = acc[i] / trials as f64;
            assert!(
                (mean - v[i]).abs() < 0.08 * norm.max(0.1),
                "biased: {} vs {}",
                mean,
                v[i]
            );
        }
    }
}
