//! Bitwise parity pins for the fixed-lane kernel dispatch
//! (`linalg::{dot, dot2, axpy, sub, sub_abs_max}` and the `DenseMat`
//! GEMV pair): whatever implementation the dispatch selects — the
//! portable scalar lane kernels, or the AVX path under
//! `--features simd` — every result must equal the lane-structured
//! scalar reference (`linalg::scalar`) bit for bit.
//!
//! The length sweep covers EVERY tail remainder: kernels stream
//! 2·LANE-wide (dot/dot2) or LANE-wide (axpy/sub/sub_abs_max) groups,
//! so lengths 0..=4·(2·LANE)+… exercise each `len % 2·LANE` and
//! `len % LANE` residue several times, plus the all-tail lengths below
//! one full group. A trainer-level leg then pins a 1-thread vs 4-thread
//! engine run bitwise, so the dispatch contract holds through the full
//! pooled trajectory, not just per call.

use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::data::synthetic;
use gdsec::linalg::{self, scalar, DenseMat, LANE};
use gdsec::objectives::Problem;
use gdsec::util::pool::Pool;
use gdsec::util::rng::Pcg64;

/// Sign-mixed values across several magnitudes (including tiny ones, so
/// a contracted fma — which the SIMD path must never emit — would show
/// up as a one-ulp mismatch).
fn vals(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg64::seeded(seed);
    (0..n)
        .map(|i| {
            let scale = match i % 4 {
                0 => 1.0,
                1 => 1e-8,
                2 => 1e8,
                _ => 1e-300,
            };
            rng.normal() * scale
        })
        .collect()
}

#[test]
fn dispatch_kernels_match_scalar_reference_across_all_tails() {
    // 0..=67 covers every residue mod 8 (= 2·LANE) and mod 4 (= LANE)
    // at least eight times, including the sub-group all-tail lengths.
    for n in 0..=(8 * 2 * LANE + 3) {
        for seed in [1u64, 2, 3] {
            let x = vals(seed, n);
            let y = vals(seed + 100, n);

            assert_eq!(
                linalg::dot(&x, &y).to_bits(),
                scalar::dot(&x, &y).to_bits(),
                "dot n={n} seed={seed}"
            );

            let (a0, a1) = linalg::dot2(&x, &y, &x);
            let (b0, b1) = scalar::dot2(&x, &y, &x);
            assert_eq!(
                (a0.to_bits(), a1.to_bits()),
                (b0.to_bits(), b1.to_bits()),
                "dot2 n={n} seed={seed}"
            );

            let mut y1 = y.clone();
            let mut y2 = y.clone();
            linalg::axpy(-1.75e-3, &x, &mut y1);
            scalar::axpy(-1.75e-3, &x, &mut y2);
            for j in 0..n {
                assert_eq!(y1[j].to_bits(), y2[j].to_bits(), "axpy n={n} j={j}");
            }

            let mut o1 = vec![0.0; n];
            let mut o2 = vec![0.0; n];
            linalg::sub(&x, &y, &mut o1);
            scalar::sub(&x, &y, &mut o2);
            for j in 0..n {
                assert_eq!(o1[j].to_bits(), o2[j].to_bits(), "sub n={n} j={j}");
            }

            let m1 = linalg::sub_abs_max(&x, &y, &mut o1);
            let m2 = scalar::sub_abs_max(&x, &y, &mut o2);
            assert_eq!(m1.to_bits(), m2.to_bits(), "sub_abs_max n={n} seed={seed}");
            for j in 0..n {
                assert_eq!(o1[j].to_bits(), o2[j].to_bits(), "sub_abs_max out n={n} j={j}");
            }
        }
    }
}

#[test]
fn gemv_pair_matches_scalar_reference_bitwise() {
    // Row counts cover the even/odd pairing split; column counts cover
    // whole-group, mixed-tail, and sub-group shapes plus a
    // multi-col-block width (> L1d/32 f64 slots).
    for (rows, cols) in [(1usize, 5usize), (2, 16), (5, 67), (8, 128), (3, 4000)] {
        let a = DenseMat { rows, cols, data: vals(7, rows * cols) };
        let x = vals(11, cols);
        let r = vals(13, rows);

        let mut out_d = vec![0.0; rows];
        let mut out_s = vec![0.0; rows];
        a.gemv(&x, &mut out_d);
        scalar::gemv(&a, &x, &mut out_s);
        for i in 0..rows {
            assert_eq!(out_d[i].to_bits(), out_s[i].to_bits(), "gemv ({rows},{cols}) i={i}");
        }

        let mut acc_d = vals(17, cols);
        let mut acc_s = acc_d.clone();
        a.gemv_t_acc(0.35, &r, &mut acc_d);
        scalar::gemv_t_acc(&a, 0.35, &r, &mut acc_s);
        for j in 0..cols {
            assert_eq!(acc_d[j].to_bits(), acc_s[j].to_bits(), "gemv_t ({rows},{cols}) j={j}");
        }
    }
}

#[test]
fn engine_trajectory_is_thread_count_invariant_under_dispatch() {
    // The whole-trainer pin: with whatever kernel path this build
    // dispatches to (scalar everywhere, AVX under `--features simd`),
    // a 1-thread and a 4-thread pooled run must produce the same
    // trajectory bit for bit — the kernels' fixed lane/fold order is
    // what makes per-element arithmetic independent of the fan-out.
    let m = 2;
    let prob = Problem::linear(synthetic::mnist_like(3, 300), m, 1.0 / 300.0);
    let cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(200.0 * m as f64),
        fstar: Some(0.0),
        eval_every: 5,
        ..Default::default()
    };
    let pool1 = Pool::new(1);
    let pool4 = Pool::new(4);
    let t1 = gdsec_algo::run_scheduled_pooled(&prob, &cfg, 20, |_k| None, &pool1);
    let t4 = gdsec_algo::run_scheduled_pooled(&prob, &cfg, 20, |_k| None, &pool4);
    assert_eq!(t1.total_bits(), t4.total_bits(), "bit accounting diverged");
    assert_eq!(t1.rows.len(), t4.rows.len());
    for (r1, r4) in t1.rows.iter().zip(t4.rows.iter()) {
        assert_eq!(r1.fval.to_bits(), r4.fval.to_bits(), "fval diverged at iter {}", r1.iter);
    }
}
