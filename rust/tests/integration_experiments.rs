//! Experiment-harness integration: every figure runner completes in quick
//! mode, writes parseable CSVs, and reproduces the paper's qualitative
//! claims (who wins, roughly by how much).

use gdsec::experiments::{run_figure, ExpContext};
use gdsec::util::csv::read_csv;

fn ctx(tag: &str) -> ExpContext {
    let dir = std::env::temp_dir().join(format!("gdsec_expit_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    ExpContext::quick(&dir)
}

#[test]
fn all_figures_run_quick_and_write_csvs() {
    let ctx = ctx("all");
    let reports = run_figure("all", &ctx).unwrap();
    assert_eq!(reports.len(), 9);
    for r in &reports {
        assert!(!r.rendered.is_empty(), "{} produced no table", r.fig);
        for f in &r.csv_files {
            let (header, rows) = read_csv(ctx.csv_path(f)).unwrap();
            assert!(!header.is_empty(), "{f}: empty header");
            assert!(!rows.is_empty(), "{f}: no rows");
            for row in &rows {
                assert_eq!(row.len(), header.len(), "{f}: ragged row");
            }
        }
    }
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn unknown_figure_rejected() {
    let ctx = ctx("bad");
    assert!(run_figure("fig99", &ctx).is_err());
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn fig1_gdsec_wins_bits_race() {
    let ctx = ctx("f1");
    let r = &run_figure("fig1", &ctx).unwrap()[0];
    // Paper: GD-SEC has by far the fewest bits to target among all six.
    let sec = r
        .headline
        .iter()
        .find(|(k, _)| k.starts_with("GD-SEC"))
        .map(|(_, v)| *v)
        .unwrap_or(f64::NAN);
    assert!(sec > 0.5, "GD-SEC savings at target too small: {sec}");
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}

#[test]
fn traces_have_monotone_bits_and_iters() {
    let ctx = ctx("mono");
    let r = &run_figure("fig2", &ctx).unwrap()[0];
    for f in &r.csv_files {
        let (header, rows) = read_csv(ctx.csv_path(f)).unwrap();
        let bit_col = header.iter().position(|h| h == "bits").unwrap();
        let iter_col = header.iter().position(|h| h == "iter").unwrap();
        let mut prev_bits = -1.0;
        let mut prev_iter = -1.0;
        for row in &rows {
            let b: f64 = row[bit_col].parse().unwrap();
            let i: f64 = row[iter_col].parse().unwrap();
            assert!(b >= prev_bits, "{f}: bits not monotone");
            assert!(i > prev_iter, "{f}: iters not strictly increasing");
            prev_bits = b;
            prev_iter = i;
        }
    }
    std::fs::remove_dir_all(&ctx.out_dir).ok();
}
