//! Property tests for the TCP stream framing layer
//! (`coordinator::tcp::FrameAssembler`).
//!
//! The framing contract the transport refactor rests on: a protocol
//! frame pushed through `frame_to_wire` → arbitrary torn-read
//! reassembly must come out byte-identical to the frame a virtual
//! channel would have delivered — for EVERY `MsgKind`, at EVERY split
//! point. Malformed streams (oversized length prefix, truncated tail)
//! must fail loudly with the offending sizes, never yield a short
//! frame.

use gdsec::compress::SparseUpdate;
use gdsec::coordinator::protocol::{self, Msg, WireFormat};
use gdsec::coordinator::tcp::{frame_to_wire, FrameAssembler, FrameError, MAX_FRAME_LEN};
use gdsec::coordinator::transport::{duplex, Recv, Transport};
use gdsec::util::rng::Pcg64;

const DIM: u32 = 7;

/// One encoded frame per `MsgKind` byte (1..=6), labeled for failure
/// messages. Kind 5 (`UpdateAdaptive`) comes from the adaptive codec on
/// a dense-ish update; the others from the default sparse path.
fn sample_frames() -> Vec<(&'static str, Vec<u8>)> {
    let d = DIM as usize;
    let mut up = SparseUpdate::empty(d);
    up.idx.push(0);
    up.idx.push(3);
    up.val.push(-1.5);
    up.val.push(0.25);
    let mut dense = SparseUpdate::empty(d);
    for j in 0..d {
        dense.idx.push(j as u32);
        dense.val.push(j as f32 - 2.0);
    }
    let theta: Vec<f64> = (0..d).map(|j| 0.1 * j as f64 - 0.3).collect();
    let frames = vec![
        (
            "broadcast",
            protocol::encode(&Msg::Broadcast { round: 3, theta, active: true }, DIM),
        ),
        (
            "update-sparse",
            protocol::encode(
                &Msg::Update { round: 4, worker: 1, update: up, local_f: 0.5 },
                DIM,
            ),
        ),
        (
            "silence",
            protocol::encode(&Msg::Silence { round: 5, worker: 2, local_f: -0.25 }, DIM),
        ),
        ("shutdown", protocol::encode(&Msg::Shutdown, DIM)),
        (
            "update-adaptive",
            protocol::encode_wire(
                &Msg::Update { round: 6, worker: 0, update: dense, local_f: 1.0 },
                DIM,
                WireFormat::Adaptive,
            ),
        ),
        ("join", protocol::encode(&Msg::Join { round: 2, worker: 1 }, DIM)),
    ];
    // The samples must actually cover every kind byte 1..=6.
    let mut kinds: Vec<u8> = frames.iter().map(|(_, f)| f[1]).collect();
    kinds.sort_unstable();
    assert_eq!(kinds, vec![1, 2, 3, 4, 5, 6], "sample frames must span every MsgKind");
    frames
}

/// Every frame kind survives reassembly split at EVERY possible tear
/// point of its wire image, byte-identically, and still decodes.
#[test]
fn every_kind_survives_every_split_point() {
    for (label, frame) in sample_frames() {
        let wire = frame_to_wire(&frame);
        for split in 1..wire.len() {
            let mut asm = FrameAssembler::new();
            let mut out = Vec::new();
            asm.push(&wire[..split]);
            let early = asm.next_into(&mut out).unwrap();
            if early {
                // A frame may only complete early if the split point
                // was past the whole wire image — impossible here.
                panic!("{label}: frame completed with only {split} of {} bytes", wire.len());
            }
            asm.push(&wire[split..]);
            assert!(asm.next_into(&mut out).unwrap(), "{label}: split {split} lost the frame");
            assert_eq!(out, frame, "{label}: split {split} corrupted the frame");
            assert!(!asm.next_into(&mut out).unwrap(), "{label}: phantom extra frame");
            asm.finish().unwrap_or_else(|e| panic!("{label}: leftover bytes: {e}"));
            protocol::decode(&out, DIM)
                .unwrap_or_else(|e| panic!("{label}: reassembled frame fails decode: {e:?}"));
        }
    }
}

/// A multi-frame stream torn at seeded-random chunk boundaries yields
/// exactly the original frame sequence. This is the torn-read path the
/// real socket exercises: many frames per read, frames spanning reads.
#[test]
fn random_tearing_over_concatenated_stream_preserves_order_and_bytes() {
    let frames = sample_frames();
    let mut stream = Vec::new();
    let mut expect: Vec<&[u8]> = Vec::new();
    for _ in 0..5 {
        for (_, f) in &frames {
            stream.extend_from_slice(&frame_to_wire(f));
            expect.push(f);
        }
    }
    let mut rng = Pcg64::new(0xF8A71, 1);
    let mut asm = FrameAssembler::new();
    let mut got = 0usize;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < stream.len() {
        let take = (1 + (rng.next_u64() % 17) as usize).min(stream.len() - i);
        asm.push(&stream[i..i + take]);
        i += take;
        while asm.next_into(&mut out).unwrap() {
            assert_eq!(out, expect[got], "frame {got} diverged under random tearing");
            got += 1;
        }
    }
    assert_eq!(got, expect.len(), "stream ended with frames missing");
    asm.finish().unwrap();
}

/// The reassembled stream path and the virtual channel path deliver
/// bitwise-identical frames — the invariant that makes TCP a pure
/// transport swap for the byte-accounted protocol.
#[test]
fn stream_path_matches_channel_path_bitwise() {
    for (label, frame) in sample_frames() {
        let (mut server, mut worker) = duplex();
        assert!(worker.send(frame.clone()));
        let via_channel = match server.recv() {
            Recv::Frame(f) => f,
            other => panic!("{label}: channel path failed: {other:?}"),
        };
        let mut asm = FrameAssembler::new();
        asm.push(&frame_to_wire(&frame));
        let via_stream = asm.next().unwrap().expect("whole wire image pushed");
        assert_eq!(via_stream, via_channel, "{label}: stream vs channel bytes diverged");
    }
}

/// An oversized length prefix is rejected before any payload is
/// buffered — a corrupt peer cannot make the server allocate 4 GiB.
#[test]
fn oversized_length_prefix_is_loud() {
    let bad_len = MAX_FRAME_LEN + 1;
    let mut wire = bad_len.to_le_bytes().to_vec();
    wire.extend_from_slice(&[0xA5, 2, 0, 0]);
    let mut asm = FrameAssembler::new();
    asm.push(&wire);
    let mut out = Vec::new();
    match asm.next_into(&mut out) {
        Err(FrameError::Oversized { len }) => {
            assert_eq!(len, bad_len);
            let msg = FrameError::Oversized { len }.to_string();
            assert!(msg.contains(&bad_len.to_string()), "error must name the offending length");
        }
        other => panic!("oversized prefix not rejected: {other:?}"),
    }
}

/// A stream that ends mid-frame reports exactly how much was buffered
/// versus needed — both mid-prefix and mid-payload.
#[test]
fn truncated_tail_is_loud_with_sizes() {
    let frames = sample_frames();
    let (_, frame) = &frames[1];
    let wire = frame_to_wire(frame);

    let mut asm = FrameAssembler::new();
    asm.push(&wire[..2]);
    assert!(!asm.next_into(&mut Vec::new()).unwrap());
    assert_eq!(asm.finish(), Err(FrameError::TruncatedTail { have: 2, need: 4 }));

    let mut asm = FrameAssembler::new();
    asm.push(&wire[..wire.len() - 3]);
    assert!(!asm.next_into(&mut Vec::new()).unwrap());
    assert_eq!(
        asm.finish(),
        Err(FrameError::TruncatedTail { have: wire.len() - 3, need: wire.len() })
    );
}
