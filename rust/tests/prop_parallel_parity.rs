//! Serial-vs-parallel trajectory parity: every trainer must produce
//! BIT-FOR-BIT identical results for any worker-pool thread count.
//!
//! The engines guarantee this by giving each worker lane exclusive state
//! and folding lanes in worker-id order on the calling thread; these
//! properties pin that contract on random linreg/logreg problems — θ, h,
//! per-worker h/e and the per-round bit accounting must match exactly
//! between a 1-thread and a 4-thread pool.

use gdsec::algo::engine::{self, CompressRule, EngineOpts};
use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::gdsec::{GdSecConfig, GdSecRule, Xi};
use gdsec::algo::trace::Trace;
use gdsec::algo::{cgd, gd, iag, qgd, sgdsec, topj};
use gdsec::compress::SparseUpdate;
use gdsec::data::{synthetic, Features};
use gdsec::objectives::{GradSplit, ObjectiveKind, Problem};
use gdsec::testing::{check_with, PropConfig};
use gdsec::util::pool::Pool;
use gdsec::util::rng::Pcg64;

const ITERS: usize = 20;

fn random_problem(rng: &mut Pcg64) -> Problem {
    let kind = if rng.bernoulli(0.5) { ObjectiveKind::LinReg } else { ObjectiveKind::LogReg };
    let n = 40 + rng.index(60);
    let m = 2 + rng.index(5); // 2..=6 workers
    Problem::new(kind, synthetic::dna_like(rng.next_u64(), n), m, 0.05)
}

fn assert_traces_bit_equal(label: &str, a: &Trace, b: &Trace) -> Result<(), String> {
    if a.rows.len() != b.rows.len() {
        return Err(format!("{label}: row count {} vs {}", a.rows.len(), b.rows.len()));
    }
    for (x, y) in a.rows.iter().zip(&b.rows) {
        if x.fval.to_bits() != y.fval.to_bits() {
            return Err(format!("{label}: iter {} fval {} vs {}", x.iter, x.fval, y.fval));
        }
        if (x.bits, x.transmissions, x.entries) != (y.bits, y.transmissions, y.entries) {
            return Err(format!(
                "{label}: iter {} accounting ({}, {}, {}) vs ({}, {}, {})",
                x.iter, x.bits, x.transmissions, x.entries, y.bits, y.transmissions, y.entries
            ));
        }
    }
    Ok(())
}

#[test]
fn prop_gdsec_serial_parallel_parity() {
    check_with(
        PropConfig { cases: 10, seed: 0xA11CE },
        "gdsec 1-thread vs 4-thread bit parity",
        |rng| {
            let prob = random_problem(rng);
            let cfg = GdSecConfig {
                alpha: 1.0 / prob.lipschitz(),
                beta: rng.uniform() * 0.3,
                xi: Xi::Uniform(rng.uniform() * 120.0),
                fstar: Some(0.0),
                ..Default::default()
            };
            // Deterministic partial-participation schedule (depends on k
            // only, so both runs see identical active sets).
            let m = prob.m();
            let schedule = |k: usize| {
                if k % 3 == 0 {
                    Some((0..m).filter(|w| (w + k) % 2 == 0).collect::<Vec<_>>())
                } else {
                    None
                }
            };
            let s = gdsec_algo::run_states(&prob, &cfg, ITERS, schedule, &Pool::new(1));
            let p = gdsec_algo::run_states(&prob, &cfg, ITERS, schedule, &Pool::new(4));
            assert_traces_bit_equal("gdsec", &s.trace, &p.trace)?;
            for i in 0..prob.d {
                if s.server.theta[i].to_bits() != p.server.theta[i].to_bits() {
                    return Err(format!("theta[{i}] diverged"));
                }
                if s.server.h[i].to_bits() != p.server.h[i].to_bits() {
                    return Err(format!("server h[{i}] diverged"));
                }
            }
            for (w, (sw, pw)) in s.workers.iter().zip(&p.workers).enumerate() {
                for i in 0..prob.d {
                    if sw.h[i].to_bits() != pw.h[i].to_bits()
                        || sw.e[i].to_bits() != pw.e[i].to_bits()
                    {
                        return Err(format!("worker {w} state diverged at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spmv_t_blocked_parity() {
    // The column-blocked/pooled CSR AᵀSpMV must equal the serial scalar
    // kernel bitwise for any thread count.
    check_with(
        PropConfig { cases: 8, seed: 0x5BA5E },
        "spmv_t_acc pooled 1/4-thread vs serial bit parity",
        |rng| {
            let rows = 20 + rng.index(60);
            let d = 50 + rng.index(400);
            let ds = synthetic::rcv1_like(rng.next_u64(), rows, d, 8);
            let Features::Sparse(a) = &ds.x else {
                return Err("rcv1_like must be sparse".to_string());
            };
            let r: Vec<f64> = (0..a.rows).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let mut serial = init.clone();
            a.spmv_t_acc(0.7, &r, &mut serial);
            for threads in [1usize, 4] {
                let pool = Pool::new(threads);
                let mut pooled = init.clone();
                a.spmv_t_acc_pooled(0.7, &r, &mut pooled, &pool);
                for j in 0..d {
                    if serial[j].to_bits() != pooled[j].to_bits() {
                        return Err(format!(
                            "threads={threads} j={j}: {} vs {}",
                            pooled[j], serial[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_grad_split_and_fstar_parity() {
    // Intra-worker row-split gradient and the pooled f* estimator: the
    // fixed lane structure makes 1-thread and 4-thread results bit-equal.
    check_with(
        PropConfig { cases: 6, seed: 0xF57A2 },
        "grad_pooled + estimate_fstar 1 vs 4 threads bit parity",
        |rng| {
            let prob = random_problem(rng);
            let theta: Vec<f64> = (0..prob.d).map(|_| rng.normal() * 0.2).collect();
            let (p1, p4) = (Pool::new(1), Pool::new(4));
            // Small row block so even these tiny shards split into
            // several lanes per worker.
            let mut s1 = GradSplit::new(&prob, 7);
            let mut s4 = GradSplit::new(&prob, 7);
            let mut g1 = vec![0.0; prob.d];
            let mut g4 = vec![0.0; prob.d];
            prob.grad_pooled(&theta, &mut g1, &mut s1, &p1);
            prob.grad_pooled(&theta, &mut g4, &mut s4, &p4);
            for j in 0..prob.d {
                if g1[j].to_bits() != g4[j].to_bits() {
                    return Err(format!("grad_pooled diverged at {j}: {} vs {}", g1[j], g4[j]));
                }
            }
            let f1 = prob.estimate_fstar_pooled(30, &p1);
            let f4 = prob.estimate_fstar_pooled(30, &p4);
            if f1.to_bits() != f4.to_bits() {
                return Err(format!("estimate_fstar diverged: {f1} vs {f4}"));
            }
            Ok(())
        },
    );
}

/// Run one rule through the engine at `threads` with a tiny nnz budget
/// (forcing multi-block nested (worker, row-block) lanes even on these
/// tiny shards) and return its trace.
fn engine_trace<R: CompressRule>(prob: &Problem, rule: R, threads: usize, budget: usize) -> Trace {
    engine::run_rule(
        prob,
        rule,
        ITERS,
        1,
        0.0,
        |_k| None,
        &Pool::new(threads),
        &EngineOpts { nnz_budget: budget, ..EngineOpts::default() },
    )
    .trace
}

#[test]
fn prop_engine_nested_lanes_parity_all_rules() {
    // The tentpole acceptance: every trainer's rule, run through the
    // unified engine with FORCED multi-block nested lanes (M < cores is
    // the regime they exist for), must produce bit-identical traces at 1
    // vs 4 threads. The block tree is fixed by (problem, budget), never
    // by the thread count.
    check_with(
        PropConfig { cases: 5, seed: 0xE7617E },
        "engine nested lanes 1 vs 4 threads bit parity (all rules)",
        |rng| {
            let prob = random_problem(rng);
            let budget = 48 + rng.index(80); // tiny ⇒ several blocks/worker
            let split = GradSplit::new_by_nnz(&prob, budget);
            if split.lanes() <= prob.m() {
                return Err(format!("budget {budget} produced no nested lanes"));
            }
            let alpha = 1.0 / prob.lipschitz();
            let d = prob.d;
            let seed = rng.next_u64();

            let gcfg = GdSecConfig {
                alpha,
                beta: 0.05,
                xi: Xi::Uniform(rng.uniform() * 80.0),
                fstar: Some(0.0),
                ..Default::default()
            };
            assert_traces_bit_equal(
                "engine/gdsec",
                &engine_trace(&prob, GdSecRule::new(gcfg.clone()), 1, budget),
                &engine_trace(&prob, GdSecRule::new(gcfg), 4, budget),
            )?;

            let c = gd::GdConfig { alpha, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "engine/gd",
                &engine_trace(&prob, gd::GdRule::new(c.clone(), d), 1, budget),
                &engine_trace(&prob, gd::GdRule::new(c, d), 4, budget),
            )?;

            let c = cgd::CgdConfig { alpha, xi: 2.0, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "engine/cgd",
                &engine_trace(&prob, cgd::CgdRule::new(c.clone(), d), 1, budget),
                &engine_trace(&prob, cgd::CgdRule::new(c, d), 4, budget),
            )?;

            let c = qgd::QgdConfig { alpha, s: 255, seed, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "engine/qgd",
                &engine_trace(&prob, qgd::QgdRule::new(c.clone(), d), 1, budget),
                &engine_trace(&prob, qgd::QgdRule::new(c, d), 4, budget),
            )?;

            let c = topj::TopJConfig {
                j: 1 + rng.index(d),
                gamma0: alpha,
                lambda: 0.05,
                eval_every: 1,
                fstar: Some(0.0),
            };
            assert_traces_bit_equal(
                "engine/topj",
                &engine_trace(&prob, topj::TopJRule::new(c.clone(), d), 1, budget),
                &engine_trace(&prob, topj::TopJRule::new(c, d), 4, budget),
            )?;

            // IAG: one sampled worker per round (deterministic schedule so
            // both thread counts see the same single-lane rounds) plus the
            // seeding round through the nested lanes.
            let c = iag::IagConfig {
                alpha: alpha / (2.0 * prob.m() as f64),
                seed,
                eval_every: 1,
                fstar: Some(0.0),
            };
            let m = prob.m();
            let iag_run = |threads: usize| {
                engine::run_rule(
                    &prob,
                    iag::IagRule::new(c.clone(), d),
                    ITERS,
                    1,
                    0.0,
                    |k| Some(vec![k % m]),
                    &Pool::new(threads),
                    &EngineOpts { nnz_budget: budget, ..EngineOpts::default() },
                )
                .trace
            };
            assert_traces_bit_equal("engine/iag", &iag_run(1), &iag_run(4))?;

            // Stochastic rules (Custom gradients — per-lane RNG streams
            // instead of nested lanes) through the same engine loop.
            for quantize_s in [None, Some(255)] {
                let c = sgdsec::SgdSecConfig {
                    gamma0: 0.05,
                    lambda: 0.01,
                    beta: 0.05,
                    xi: Xi::Uniform(30.0),
                    batch: 1 + rng.index(3),
                    seed,
                    quantize_s,
                    eval_every: 1,
                    fstar: Some(0.0),
                };
                assert_traces_bit_equal(
                    "engine/sgdsec",
                    &engine_trace(&prob, sgdsec::SgdSecRule::new(c.clone()), 1, budget),
                    &engine_trace(&prob, sgdsec::SgdSecRule::new(c.clone()), 4, budget),
                )?;
                assert_traces_bit_equal(
                    "engine/sgd",
                    &engine_trace(&prob, sgdsec::SgdRule::new(c.clone(), d), 1, budget),
                    &engine_trace(&prob, sgdsec::SgdRule::new(c, d), 4, budget),
                )?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_quorum_stale_fold_parity() {
    // Semi-synchronous rounds: a deterministic late-lane schedule (the
    // quorum cut's output) must still produce bit-identical trajectories
    // at 1 vs 4 threads — the stale folds happen sequentially in worker
    // order, never on the pool.
    check_with(
        PropConfig { cases: 6, seed: 0x57A1E },
        "engine quorum stale-fold 1 vs 4 threads bit parity",
        |rng| {
            let prob = random_problem(rng);
            let m = prob.m();
            let cfg = GdSecConfig {
                alpha: 1.0 / prob.lipschitz(),
                beta: rng.uniform() * 0.3,
                xi: Xi::Uniform(rng.uniform() * 80.0),
                fstar: Some(0.0),
                ..Default::default()
            };
            let budget = 48 + rng.index(80); // force multi-block nested lanes
            let run = |threads: usize| {
                let pool = Pool::new(threads);
                let opts = EngineOpts { nnz_budget: budget, ..EngineOpts::default() };
                let rule = GdSecRule::new(cfg.clone());
                let mut eng = engine::Engine::new(&prob, rule, &pool, &opts, 0.0);
                eng.record();
                for k in 1..=ITERS {
                    let late = [(k + 1) % m]; // rotate the straggler
                    eng.step_quorum(None, Some(&late));
                    eng.record();
                }
                eng.into_run()
            };
            let s = run(1);
            let p = run(4);
            assert_traces_bit_equal("engine-quorum", &s.trace, &p.trace)?;
            if s.trace.total_stale() == 0 {
                return Err("quorum run never folded a stale update".into());
            }
            if s.trace.total_stale() != p.trace.total_stale() {
                return Err("stale accounting diverged across thread counts".into());
            }
            for i in 0..prob.d {
                if s.server.theta[i].to_bits() != p.server.theta[i].to_bits()
                    || s.server.h[i].to_bits() != p.server.h[i].to_bits()
                {
                    return Err(format!("server state diverged at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_quorum_window_adaptive_parity() {
    // The tentpole contract: a multi-round staleness window (S > 1, aged
    // parks through `step_quorum_aged`) driven by the delay-adaptive
    // quorum controller (`QuorumSim` mirrors the coordinator's
    // decide-K → cut → observe loop) must still produce bit-identical
    // trajectories and server state at 1 vs 4 threads — the cut, ages,
    // and EMA state depend only on the deterministic DelayPlan, never on
    // the pool.
    use gdsec::coordinator::round::Quorum;
    use gdsec::coordinator::scheduler::QuorumSim;
    use gdsec::coordinator::transport::DelayPlan;
    check_with(
        PropConfig { cases: 6, seed: 0xADA97 },
        "engine aged-quorum + adaptive scheduler 1 vs 4 threads bit parity",
        |rng| {
            let prob = random_problem(rng);
            let m = prob.m();
            let window = 2 + rng.index(2); // S ∈ {2, 3}
            let cfg = GdSecConfig {
                alpha: 1.0 / prob.lipschitz(),
                beta: rng.uniform() * 0.3,
                xi: Xi::Uniform(rng.uniform() * 80.0),
                fstar: Some(0.0),
                ..Default::default()
            };
            // One hard straggler whose identity flips mid-run, fast
            // cluster jittered by worker id — forces real cuts, aged
            // parks, and an EMA that actually moves.
            let mut early: Vec<u64> = (0..m).map(|w| 2 + w as u64).collect();
            let mut late_phase = early.clone();
            early[m - 1] = 400;
            late_phase[0] = 400;
            let plan = DelayPlan::Phased(vec![(1, early), (ITERS / 2, late_phase)]);
            let quorum = Quorum::Adaptive {
                target_quantile: 0.4 + rng.uniform() * 0.3,
                min_frac: 0.3,
            };
            let budget = 48 + rng.index(80); // force multi-block nested lanes
            let run = |threads: usize| {
                let pool = Pool::new(threads);
                let opts = EngineOpts {
                    nnz_budget: budget,
                    stale_window: window,
                    ..EngineOpts::default()
                };
                let mut sim = QuorumSim::new(m, quorum, plan.clone(), window);
                let mut eng =
                    engine::Engine::new(&prob, GdSecRule::new(cfg.clone()), &pool, &opts, 0.0);
                eng.record();
                for k in 1..=ITERS {
                    let (late, _units) = sim.round(k, None);
                    eng.step_quorum_aged(None, Some(late));
                    eng.record();
                }
                eng.into_run()
            };
            let s = run(1);
            let p = run(4);
            assert_traces_bit_equal("engine-aged-quorum", &s.trace, &p.trace)?;
            if s.trace.total_stale() == 0 {
                return Err("aged-quorum run never folded a stale update".into());
            }
            let (sl, pl) = (s.trace.rows.last().unwrap(), p.trace.rows.last().unwrap());
            if sl.stale_ages != pl.stale_ages {
                return Err("stale-age histograms diverged across thread counts".into());
            }
            // The hard bound: no fold older than the window, and the
            // multi-round path was actually exercised.
            if sl.stale_ages.iter().skip(window).any(|&c| c > 0) {
                return Err(format!("fold beyond the S={window} window: {:?}", sl.stale_ages));
            }
            if sl.stale_ages.iter().skip(1).take(window - 1).sum::<u64>() == 0 {
                return Err("no multi-round (age > 1) fold ever happened".into());
            }
            for i in 0..prob.d {
                if s.server.theta[i].to_bits() != p.server.theta[i].to_bits()
                    || s.server.h[i].to_bits() != p.server.h[i].to_bits()
                {
                    return Err(format!("server state diverged at {i}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gdsec_nested_schedule_parity_and_states() {
    // Nested lanes + partial participation through the public
    // run_states_opts surface: server AND worker states bit-equal.
    check_with(
        PropConfig { cases: 4, seed: 0x9E57ED },
        "gdsec nested lanes + schedule 1 vs 4 threads",
        |rng| {
            let prob = random_problem(rng);
            let opts = EngineOpts { nnz_budget: 40 + rng.index(60), ..EngineOpts::default() };
            let cfg = GdSecConfig {
                alpha: 1.0 / prob.lipschitz(),
                beta: rng.uniform() * 0.3,
                xi: Xi::Uniform(rng.uniform() * 120.0),
                fstar: Some(0.0),
                ..Default::default()
            };
            let m = prob.m();
            let schedule = |k: usize| {
                if k % 3 == 0 {
                    Some((0..m).filter(|w| (w + k) % 2 == 0).collect::<Vec<_>>())
                } else {
                    None
                }
            };
            let s =
                gdsec_algo::run_states_opts(&prob, &cfg, ITERS, schedule, &Pool::new(1), &opts);
            let p =
                gdsec_algo::run_states_opts(&prob, &cfg, ITERS, schedule, &Pool::new(4), &opts);
            assert_traces_bit_equal("gdsec-nested", &s.trace, &p.trace)?;
            for i in 0..prob.d {
                if s.server.theta[i].to_bits() != p.server.theta[i].to_bits()
                    || s.server.h[i].to_bits() != p.server.h[i].to_bits()
                {
                    return Err(format!("server state diverged at {i}"));
                }
            }
            for (w, (sw, pw)) in s.workers.iter().zip(&p.workers).enumerate() {
                for i in 0..prob.d {
                    if sw.h[i].to_bits() != pw.h[i].to_bits()
                        || sw.e[i].to_bits() != pw.e[i].to_bits()
                    {
                        return Err(format!("worker {w} state diverged at {i}"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// A random wire-shaped sparse update: strictly increasing indices,
/// f32 values (exactly what the coordinator admits off the link).
fn random_update(rng: &mut Pcg64, d: usize) -> SparseUpdate {
    let nnz = rng.index(d + 1);
    let mut picked = rng.sample_indices(d, nnz);
    picked.sort_unstable();
    let mut u = SparseUpdate::empty(d);
    for i in picked {
        u.idx.push(i as u32);
        u.val.push((rng.normal() * 2.0) as f32);
    }
    u
}

#[test]
fn prop_sharded_fold_serial_parity() {
    // The coordinate-sharded server fold (persistent ShardPlan: per-shard
    // subrange cuts, fold_scale rescale, θ/h step, in-pass h-share
    // booking) must be BITWISE identical to the serial reference — plain
    // `add_into` accumulation in the same staged order, then the scalar
    // step and ledger loops — over random stale/fresh mixes, for every
    // shard count in {1, 3, 7}, fold_scale ∈ {1.0, M/live}, and 1 vs 4
    // threads. Shard boundaries never cross a coordinate, so the cut
    // count must not leak into a single bit of θ, h, agg, or the ledger.
    use gdsec::coordinator::round::StaleUpdate;
    use gdsec::util::shard::{ShardApply, ShardPlan};
    check_with(
        PropConfig { cases: 12, seed: 0x5AA2DED },
        "sharded fold vs serial add_into fold bit parity",
        |rng| {
            let d = 1 + rng.index(500);
            let m = 1 + rng.index(6);
            let (alpha, beta) = (rng.uniform() * 0.5, rng.uniform() * 0.5);
            // Random stale mix: 0..=3 due entries in (round, worker)
            // order, then random fresh updates (some workers silent).
            let n_stale = rng.index(4);
            let due: Vec<StaleUpdate> = (0..n_stale)
                .map(|i| StaleUpdate {
                    round: 1 + i as u32,
                    worker: rng.index(m),
                    age: 1,
                    update: random_update(rng, d),
                })
                .collect();
            let fresh: Vec<Option<SparseUpdate>> = (0..m)
                .map(|_| rng.bernoulli(0.7).then(|| random_update(rng, d)))
                .collect();
            let theta0: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let h0: Vec<f64> = (0..d).map(|_| rng.normal() * 0.1).collect();
            let live = 1 + rng.index(m);
            for fold_scale in [1.0, m as f64 / live as f64] {
                // Serial reference: accumulate in staged order, rescale,
                // step, book — scalar loops, no pool, no shards.
                let mut agg_ref = vec![0.0f64; d];
                for s in &due {
                    s.update.add_into(&mut agg_ref);
                }
                for u in fresh.iter().flatten() {
                    u.add_into(&mut agg_ref);
                }
                if fold_scale != 1.0 {
                    for v in agg_ref.iter_mut() {
                        *v *= fold_scale;
                    }
                }
                let mut theta_ref = theta0.clone();
                let mut h_ref = h0.clone();
                for j in 0..d {
                    theta_ref[j] -= alpha * (h_ref[j] + agg_ref[j]);
                    h_ref[j] += beta * agg_ref[j];
                }
                let bs = beta * fold_scale;
                let mut shares_ref = vec![vec![0.0f64; d]; m];
                for s in &due {
                    for (&i, &v) in s.update.idx.iter().zip(s.update.val.iter()) {
                        shares_ref[s.worker][i as usize] += bs * v as f64;
                    }
                }
                for (w, u) in fresh.iter().enumerate() {
                    if let Some(u) = u {
                        for (&i, &v) in u.idx.iter().zip(u.val.iter()) {
                            shares_ref[w][i as usize] += bs * v as f64;
                        }
                    }
                }
                for shards in [1usize, 3, 7] {
                    for threads in [1usize, 4] {
                        let pool = Pool::new(threads);
                        let mut plan = ShardPlan::with_shards(shards);
                        let mut theta = theta0.clone();
                        let mut h = h0.clone();
                        let mut agg = vec![0.0f64; d];
                        let mut shares = vec![vec![0.0f64; d]; m];
                        plan.fold(
                            &pool,
                            due.iter().map(|s| (s.worker, &s.update)).chain(
                                fresh
                                    .iter()
                                    .enumerate()
                                    .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                            ),
                            ShardApply {
                                theta: &mut theta,
                                h: &mut h,
                                agg: &mut agg,
                                theta_prev: None,
                                alpha,
                                beta,
                                state_variable: true,
                                fold_scale,
                                staged_agg: false,
                                shares: Some((&mut shares, bs)),
                            },
                        );
                        for j in 0..d {
                            if theta[j].to_bits() != theta_ref[j].to_bits()
                                || h[j].to_bits() != h_ref[j].to_bits()
                                || agg[j].to_bits() != agg_ref[j].to_bits()
                            {
                                return Err(format!(
                                    "θ/h/agg diverged at j={j} (d={d} m={m} shards={shards} \
                                     threads={threads} scale={fold_scale})"
                                ));
                            }
                        }
                        for w in 0..m {
                            for j in 0..d {
                                if shares[w][j].to_bits() != shares_ref[w][j].to_bits() {
                                    return Err(format!(
                                        "h-share ledger diverged at w={w} j={j} \
                                         (shards={shards} threads={threads})"
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            // Engine mode on top: staged agg (stale pre-folded via
            // fold_update semantics), θ_prev snapshot, no booking — the
            // serial oracle is ServerState::apply_round itself.
            {
                let mut sref = gdsec_algo::ServerState::new(d);
                sref.theta.copy_from_slice(&theta0);
                sref.h.copy_from_slice(&h0);
                let cfg = GdSecConfig { alpha, beta, fstar: Some(0.0), ..Default::default() };
                for s in &due {
                    sref.fold_update(&s.update);
                }
                sref.apply_round(&cfg, fresh.iter().flatten());
                for shards in [1usize, 3, 7] {
                    for threads in [1usize, 4] {
                        let pool = Pool::new(threads);
                        let mut plan = ShardPlan::with_shards(shards);
                        let mut theta = theta0.clone();
                        let mut prev = vec![0.0f64; d];
                        let mut h = h0.clone();
                        let mut agg = vec![0.0f64; d];
                        for s in &due {
                            s.update.add_into(&mut agg);
                        }
                        plan.fold(
                            &pool,
                            fresh
                                .iter()
                                .enumerate()
                                .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                            ShardApply {
                                theta: &mut theta,
                                h: &mut h,
                                agg: &mut agg,
                                theta_prev: Some(&mut prev),
                                alpha,
                                beta,
                                state_variable: true,
                                fold_scale: 1.0,
                                staged_agg: true,
                                shares: None,
                            },
                        );
                        for j in 0..d {
                            if theta[j].to_bits() != sref.theta[j].to_bits()
                                || h[j].to_bits() != sref.h[j].to_bits()
                                || prev[j].to_bits() != sref.theta_prev[j].to_bits()
                                || agg[j] != 0.0
                            {
                                return Err(format!(
                                    "engine-mode fold diverged from apply_round at j={j} \
                                     (shards={shards} threads={threads})"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_baselines_serial_parallel_parity() {
    check_with(
        PropConfig { cases: 6, seed: 0xB0B },
        "baselines 1-thread vs 4-thread bit parity",
        |rng| {
            let prob = random_problem(rng);
            let alpha = 1.0 / prob.lipschitz();
            let (p1, p4) = (Pool::new(1), Pool::new(4));

            let c = gd::GdConfig { alpha, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "gd",
                &gd::run_scheduled_pooled(&prob, &c, ITERS, |_k| None, &p1),
                &gd::run_scheduled_pooled(&prob, &c, ITERS, |_k| None, &p4),
            )?;

            let c = cgd::CgdConfig { alpha, xi: 2.0, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "cgd",
                &cgd::run_pooled(&prob, &c, ITERS, &p1),
                &cgd::run_pooled(&prob, &c, ITERS, &p4),
            )?;

            let seed = rng.next_u64();
            let c = qgd::QgdConfig { alpha, s: 255, seed, eval_every: 1, fstar: Some(0.0) };
            assert_traces_bit_equal(
                "qgd",
                &qgd::run_pooled(&prob, &c, ITERS, &p1),
                &qgd::run_pooled(&prob, &c, ITERS, &p4),
            )?;

            let c = topj::TopJConfig {
                j: 1 + rng.index(prob.d),
                gamma0: alpha,
                lambda: 0.05,
                eval_every: 1,
                fstar: Some(0.0),
            };
            assert_traces_bit_equal(
                "topj",
                &topj::run_pooled(&prob, &c, ITERS, &p1),
                &topj::run_pooled(&prob, &c, ITERS, &p4),
            )?;

            let c = iag::IagConfig {
                alpha: alpha / (2.0 * prob.m() as f64),
                seed,
                eval_every: 1,
                fstar: Some(0.0),
            };
            assert_traces_bit_equal(
                "iag",
                &iag::run_pooled(&prob, &c, ITERS, &p1),
                &iag::run_pooled(&prob, &c, ITERS, &p4),
            )?;

            for quantize_s in [None, Some(255)] {
                let c = sgdsec::SgdSecConfig {
                    gamma0: 0.05,
                    lambda: 0.01,
                    beta: 0.05,
                    xi: Xi::Uniform(30.0),
                    batch: 1 + rng.index(3),
                    seed,
                    quantize_s,
                    eval_every: 1,
                    fstar: Some(0.0),
                };
                assert_traces_bit_equal(
                    if quantize_s.is_some() { "qsgdsec" } else { "sgdsec" },
                    &sgdsec::run_sgdsec_pooled(&prob, &c, ITERS, &p1),
                    &sgdsec::run_sgdsec_pooled(&prob, &c, ITERS, &p4),
                )?;
                assert_traces_bit_equal(
                    "sgd",
                    &sgdsec::run_sgd_pooled(&prob, &c, ITERS, &p1),
                    &sgdsec::run_sgd_pooled(&prob, &c, ITERS, &p4),
                )?;
            }
            Ok(())
        },
    );
}
