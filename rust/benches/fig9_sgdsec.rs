//! `cargo bench --bench fig9_sgdsec` — regenerates the paper's fig9
//! (stochastic SGD-SEC / QSGD-SEC) at full size and reports wall time.
//! Set GDSEC_BENCH_QUICK=1 for a reduced-size smoke run.

use gdsec::experiments::{run_figure, ExpContext};
use gdsec::util::Timer;

fn main() {
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut ctx = ExpContext::new("results");
    ctx.quick = quick;
    let t = Timer::start();
    let reports = run_figure("fig9", &ctx).expect("fig9");
    for r in &reports {
        r.print();
    }
    println!("[bench] fig9 wall time: {:.2}s (quick={quick})", t.elapsed_secs());
}
