//! Federated scale-out bench: cohort-sampled GD-SEC rounds at
//! M ∈ {100, 1k, 10k} workers through the thread-free
//! [`federated`](gdsec::coordinator::federated) harness (custom harness
//! — no criterion offline).
//!
//! Two sweep axes per fleet size: full participation (`c100`, every
//! worker every round — the engine-equivalent baseline) and a 10%
//! seeded cohort (`c10`) with the default idle-horizon ledger eviction.
//! Reported per point: rounds/sec over the virtual transport and total
//! uplink bits. Memory telemetry per fleet size: peak server
//! per-worker-state bytes with the evictable [`StateStore`]
//! (`resident_state_bytes_m{M}_c10`) against an always-resident O(M·d)
//! replica of the same cohort schedule
//! (`resident_state_bytes_dense_m{M}`), plus the ratio
//! (`federated_state_bytes_ratio_m{M}_c10`).
//!
//! Before any timing, the evicting store is pinned BITWISE against the
//! always-resident replica — θ, h, every per-worker ledger, and the
//! uplink byte count must be identical; eviction is a memory layout
//! choice, never an arithmetic one. The byte accounting is
//! deterministic (slab/parked lengths, no allocator probing), so the
//! ratio floor at M = 10k (≥ 5×, the rare-feature regime) is asserted
//! here in-bench; `federated_speedup_m10000_c10` (evicting vs dense
//! replica wall-clock) is informational — wall times are not CI-stable.
//!
//! Results are printed AND written to `BENCH_federated.json` at the
//! repo root (override with `GDSEC_BENCH_OUT`), schema `gdsec-bench-v1`;
//! see EXPERIMENTS.md §Federated scale. `GDSEC_BENCH_QUICK=1` shortens
//! the timing windows (same keys). `GDSEC_THREADS`/`GDSEC_SHARDS`
//! steer the server fold exactly as in the coordinator.

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::federated::{run_federated, FederatedConfig, FederatedOutcome};
use gdsec::coordinator::scheduler::{CohortPlan, DEFAULT_COHORT_SEED};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::bench::{self, BenchStats, Bencher};
use gdsec::util::json::Json;
use gdsec::util::pool::Pool;
use std::path::PathBuf;

/// Model dimension for every sweep point. With ~8 features per local
/// shard (the rare-feature regime of sparse federated corpora), each
/// worker's ledger touches a handful of the 256 coordinates — the
/// regime where parking a ledger in compact (idx, val) form beats a
/// dense slab by ~20×.
const DIM: usize = 256;
/// Average nonzero features per data row.
const AVG_NNZ: usize = 8;
/// Rounds per timed run (fresh state each call; both layouts pay the
/// same setup).
const ITERS: usize = 20;

fn gd_cfg() -> GdSecConfig {
    GdSecConfig {
        alpha: 0.05,
        beta: 0.5,
        xi: Xi::Uniform(0.3),
        fstar: Some(0.0),
        eval_every: 1,
        ..GdSecConfig::default()
    }
}

fn problem(m: usize) -> Problem {
    let ds = synthetic::rcv1_like(42, m, DIM, AVG_NNZ);
    Problem::logistic(ds, m, 0.0)
}

/// One federated run: `cohort_pct` = 100 (full participation, dense
/// always-resident ledger — the engine layout) or 10 (seeded 10%
/// cohort). `dense_replica` forces the O(M·d) always-resident store
/// under the SAME cohort schedule (the memory baseline).
fn run_one(prob: &Problem, cohort_pct: usize, dense_replica: bool, pool: &Pool) -> FederatedOutcome {
    let mut fc = FederatedConfig::new(gd_cfg(), ITERS);
    fc.eval_every = 0;
    if cohort_pct < 100 {
        fc.cohort = Some(CohortPlan::fraction(cohort_pct as f64 / 100.0, DEFAULT_COHORT_SEED));
    }
    if dense_replica {
        // u32::MAX horizon: slabs materialize on first transmission and
        // never age out — O(M·d) resident, identical arithmetic.
        fc.evict_after = Some(u32::MAX);
    }
    run_federated(prob, fc, pool)
}

fn rps_key(m: usize, c: usize) -> &'static str {
    match (m, c) {
        (100, 100) => "federated_rounds_per_sec_m100_c100",
        (100, 10) => "federated_rounds_per_sec_m100_c10",
        (1000, 100) => "federated_rounds_per_sec_m1000_c100",
        (1000, 10) => "federated_rounds_per_sec_m1000_c10",
        (10000, 100) => "federated_rounds_per_sec_m10000_c100",
        (10000, 10) => "federated_rounds_per_sec_m10000_c10",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn bits_key(m: usize, c: usize) -> &'static str {
    match (m, c) {
        (100, 100) => "federated_uplink_bits_m100_c100",
        (100, 10) => "federated_uplink_bits_m100_c10",
        (1000, 100) => "federated_uplink_bits_m1000_c100",
        (1000, 10) => "federated_uplink_bits_m1000_c10",
        (10000, 100) => "federated_uplink_bits_m10000_c100",
        (10000, 10) => "federated_uplink_bits_m10000_c10",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn state_key(m: usize) -> &'static str {
    match m {
        100 => "resident_state_bytes_m100_c10",
        1000 => "resident_state_bytes_m1000_c10",
        10000 => "resident_state_bytes_m10000_c10",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn dense_key(m: usize) -> &'static str {
    match m {
        100 => "resident_state_bytes_dense_m100",
        1000 => "resident_state_bytes_dense_m1000",
        10000 => "resident_state_bytes_dense_m10000",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn ratio_key(m: usize) -> &'static str {
    match m {
        100 => "federated_state_bytes_ratio_m100_c10",
        1000 => "federated_state_bytes_ratio_m1000_c10",
        10000 => "federated_state_bytes_ratio_m10000_c10",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn out_path() -> PathBuf {
    if let Ok(p) = std::env::var("GDSEC_BENCH_OUT") {
        return PathBuf::from(p);
    }
    // rust/ -> repo root
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(&manifest).join("BENCH_federated.json")
}

fn to_bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let pool = Pool::from_env();
    let mut reports: Vec<BenchStats> = Vec::new();
    let mut context: Vec<(&str, Json)> = vec![
        ("bench", Json::str("federated_scale")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(pool.threads() as f64)),
        ("dim", Json::num(DIM as f64)),
        ("iters_per_run", Json::num(ITERS as f64)),
    ];

    for &m in &[100usize, 1000, 10000] {
        let prob = problem(m);

        // Bitwise parity gate before any timing: the evicting store vs
        // the always-resident replica under the identical cohort
        // schedule — same θ, h, ledgers, and uplink bytes.
        let evicting = run_one(&prob, 10, false, &pool);
        let dense = run_one(&prob, 10, true, &pool);
        assert_eq!(
            to_bits(&evicting.theta),
            to_bits(&dense.theta),
            "evicting/dense θ parity broke at M={m}"
        );
        assert_eq!(to_bits(&evicting.h), to_bits(&dense.h), "h parity broke at M={m}");
        let mut la = vec![0.0; DIM];
        let mut lb = vec![0.0; DIM];
        for w in 0..m {
            evicting.store.ledger_dense(w, &mut la);
            dense.store.ledger_dense(w, &mut lb);
            assert_eq!(to_bits(&la), to_bits(&lb), "ledger parity broke at M={m} worker {w}");
        }
        assert_eq!(evicting.uplink_bits, dense.uplink_bits, "uplink bits diverged at M={m}");
        assert!(evicting.evictions > 0, "evicting store never cycled at M={m}");
        assert_eq!(dense.evictions, 0, "dense replica must never evict");

        // Deterministic memory telemetry (length-based accounting:
        // resident slabs × 8 B/coord + parked entries × 12 B/entry).
        let ratio = dense.peak_state_bytes as f64 / evicting.peak_state_bytes.max(1) as f64;
        context.push((state_key(m), Json::num(evicting.peak_state_bytes as f64)));
        context.push((dense_key(m), Json::num(dense.peak_state_bytes as f64)));
        context.push((ratio_key(m), Json::num(ratio)));
        if m == 10000 {
            assert!(
                ratio >= 5.0,
                "O(cohort) state floor broke: dense {} B vs evicting {} B ({ratio:.2}x < 5x)",
                dense.peak_state_bytes,
                evicting.peak_state_bytes
            );
        }

        // --- rounds/sec sweep: full participation and 10% cohort ---
        let mut speedup_base_ns = None;
        for &c in &[100usize, 10] {
            let stats = b.run_units(
                &format!("federated M={m} cohort={c}% t={}", pool.threads()),
                ITERS as f64,
                "round",
                || {
                    std::hint::black_box(run_one(&prob, c, false, &pool));
                },
            );
            let bits = run_one(&prob, c, false, &pool).uplink_bits;
            context.push((rps_key(m, c), Json::num(stats.throughput().unwrap_or(0.0))));
            context.push((bits_key(m, c), Json::num(bits as f64)));
            if m == 10000 && c == 10 {
                speedup_base_ns = Some(stats.mean_ns);
            }
            reports.push(stats);
        }

        // --- O(M)-state replica wall-clock at the 10k saturation point
        //     (informational: eviction must not cost throughput) ---
        if m == 10000 {
            let dense_stats = b.run_units(
                &format!("federated M={m} cohort=10% dense-replica t={}", pool.threads()),
                ITERS as f64,
                "round",
                || {
                    std::hint::black_box(run_one(&prob, 10, true, &pool));
                },
            );
            if let Some(evict_ns) = speedup_base_ns {
                context.push(("federated_speedup_m10000_c10", Json::num(dense_stats.mean_ns / evict_ns)));
            }
            reports.push(dense_stats);
        }
    }

    println!("\n== federated scale ==");
    for r in &reports {
        println!("{}", r.report());
    }
    for (k, v) in &context {
        if let Some(x) = v.as_f64() {
            println!("{k}: {x:.2}");
        }
    }
    let path = out_path();
    match bench::write_json(&path, context, &reports) {
        Ok(()) => println!("bench artifact -> {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
