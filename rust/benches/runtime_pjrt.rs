//! PJRT runtime benchmarks: artifact compile time and request-path
//! execution latency for the compiled worker step, the standalone Pallas
//! sparsify kernel, and the transformer loss+grad.
//!
//! Skipped (with a message) when `make artifacts` hasn't run.

use gdsec::data::{synthetic, Features};
use gdsec::objectives::{ObjectiveKind, Problem};
use gdsec::runtime::engine::{TfmEngine, WorkerScalars, XlaWorkerStep};
use gdsec::runtime::{Manifest, Runtime};
use gdsec::util::bench::Bencher;
use gdsec::util::Timer;

fn main() {
    let man = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("SKIP runtime_pjrt: {e:#}");
            return;
        }
    };
    let b = Bencher::from_env();
    let mut reports = Vec::new();

    // --- compile latency (cold) ---
    let t = Timer::start();
    let mut rt = Runtime::new(man.clone()).unwrap();
    rt.load("worker_step_logreg_30x180").unwrap();
    println!("cold client+compile worker_step_logreg: {:.1} ms", t.elapsed_ms());

    // --- worker step execute latency ---
    let prob = Problem::new(ObjectiveKind::LogReg, synthetic::dna_like(23, 90), 3, 0.05);
    let l = &prob.locals[0];
    let (x, y) = match &l.shard.x {
        Features::Dense(m) => (m.data.clone(), l.shard.y.clone()),
        _ => unreachable!(),
    };
    let mut step = XlaWorkerStep::new(man.clone(), "worker_step_logreg_30x180", &x, &y).unwrap();
    let d = prob.d;
    let theta = vec![0.01; d];
    let zeros32 = vec![0.0f32; d];
    let zeros64 = vec![0.0f64; d];
    let scal = WorkerScalars { beta: 0.01, m_inv: 1.0 / 3.0, n_inv: 1.0 / 90.0, lambda: 0.05 };
    reports.push(b.run("pjrt worker_step 30x180 (grad+pallas sparsify)", || {
        let out = step.step(&theta, &theta, &zeros32, &zeros32, &zeros64, scal).unwrap();
        std::hint::black_box(out.loss);
    }));

    // --- transformer loss+grad latency ---
    match TfmEngine::new(man) {
        Ok(mut eng) => {
            let params = eng.init_params(1).unwrap();
            let corpus = synthetic::token_corpus(2, eng.batch, eng.seq, eng.vocab);
            let tokens: Vec<i32> =
                corpus.iter().flat_map(|s| s.iter().map(|&t| t as i32)).collect();
            let toks = (eng.batch * eng.seq) as f64;
            reports.push(b.run_units(
                &format!("pjrt tfm_loss_grad ({} params)", eng.n_params),
                toks,
                "token",
                || {
                    let (loss, g) = eng.loss_grad(&params, &tokens).unwrap();
                    std::hint::black_box((loss, g[0]));
                },
            ));
            let dp = eng.n_params;
            let grad = vec![0.01f32; dp];
            let zeros = vec![0.0f32; dp];
            let diff = vec![1e-3f32; dp];
            reports.push(b.run_units(
                &format!("pjrt pallas gdsec_sparsify d={dp}"),
                dp as f64,
                "elem",
                || {
                    let (w, _, _) =
                        eng.sparsify(&grad, &zeros, &zeros, &diff, 100.0, 0.01, 0.25).unwrap();
                    std::hint::black_box(w[0]);
                },
            ));
        }
        Err(e) => println!("tfm engine unavailable: {e:#}"),
    }

    println!("\n== PJRT runtime benchmarks ==");
    for r in &reports {
        println!("{}", r.report());
    }
}
