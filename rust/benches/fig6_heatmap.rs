//! `cargo bench --bench fig6_heatmap` — regenerates the paper's fig6
//! (per-worker/coordinate transmission heatmap) at full size and reports wall time.
//! Set GDSEC_BENCH_QUICK=1 for a reduced-size smoke run.

use gdsec::experiments::{run_figure, ExpContext};
use gdsec::util::Timer;

fn main() {
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut ctx = ExpContext::new("results");
    ctx.quick = quick;
    let t = Timer::start();
    let reports = run_figure("fig6", &ctx).expect("fig6");
    for r in &reports {
        r.print();
    }
    println!("[bench] fig6 wall time: {:.2}s (quick={quick})", t.elapsed_secs());
}
