//! `cargo bench --bench fig1_linreg` — regenerates the paper's fig1
//! (linear regression, MNIST-like, 6 algorithms) at full size and reports wall time.
//! Set GDSEC_BENCH_QUICK=1 for a reduced-size smoke run.

use gdsec::experiments::{run_figure, ExpContext};
use gdsec::util::Timer;

fn main() {
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut ctx = ExpContext::new("results");
    ctx.quick = quick;
    let t = Timer::start();
    let reports = run_figure("fig1", &ctx).expect("fig1");
    for r in &reports {
        r.print();
    }
    println!("[bench] fig1 wall time: {:.2}s (quick={quick})", t.elapsed_secs());
}
