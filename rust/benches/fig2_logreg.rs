//! `cargo bench --bench fig2_logreg` — regenerates the paper's fig2
//! (logistic regression, paper synthetic recipe) at full size and reports wall time.
//! Set GDSEC_BENCH_QUICK=1 for a reduced-size smoke run.

use gdsec::experiments::{run_figure, ExpContext};
use gdsec::util::Timer;

fn main() {
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut ctx = ExpContext::new("results");
    ctx.quick = quick;
    let t = Timer::start();
    let reports = run_figure("fig2", &ctx).expect("fig2");
    for r in &reports {
        r.print();
    }
    println!("[bench] fig2 wall time: {:.2}s (quick={quick})", t.elapsed_secs());
}
