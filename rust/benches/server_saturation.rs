//! Server saturation bench: update-absorption throughput of the
//! coordinate-sharded server fold (custom harness — no criterion
//! offline).
//!
//! Measures how fast the server absorbs a round of admitted
//! [`SparseUpdate`]s at fixed model dimension (d = 262144): the full
//! per-round server work — zero/stage the aggregate, fold every update
//! through the persistent [`ShardPlan`], step θ/h, book the per-worker
//! h-share ledgers — swept over worker count (M ∈ {4, 16, 64} synthetic
//! providers) and update density (nnz ∈ {256, 4096, 32768}). Reported
//! as updates/sec and MB/s of absorbed wire traffic (decoded payload
//! bytes per round / round time).
//!
//! A verbatim replica of the pre-shard `apply_round_blocked` (one column
//! block per pool thread, per-(block, update) `add_range_into` binary
//! search, post-apply full-scan `book_shares`) is timed at M = 64 as the
//! seed baseline; `server_sharded_speedup_m64*` context keys track the
//! ratio. Before any timing, both paths are checked for BITWISE parity
//! on θ, h, agg, and the ledgers — the shard plan must be a pure
//! reorganization of the same arithmetic.
//!
//! Results are printed AND written to `BENCH_server.json` at the repo
//! root (override with `GDSEC_BENCH_OUT`), schema `gdsec-bench-v1`; see
//! EXPERIMENTS.md §Server saturation. Set `GDSEC_BENCH_QUICK=1` for the
//! CI smoke run (same keys, shorter timing windows). `GDSEC_SHARDS` and
//! `GDSEC_THREADS` steer the plan/pool exactly as in the coordinator.

use gdsec::algo::gdsec::GdSecConfig;
use gdsec::compress::{self, SparseUpdate};
use gdsec::coordinator::round::StaleUpdate;
use gdsec::linalg;
use gdsec::util::bench::{self, BenchStats, Bencher};
use gdsec::util::json::Json;
use gdsec::util::pool::Pool;
use gdsec::util::rng::Pcg64;
use gdsec::util::shard::{ShardApply, ShardPlan, ShareBook};
use std::path::PathBuf;

/// The model dimension for every sweep point (quick mode included, so
/// the JSON keys stay identical run-over-run): 2 MiB of f64 per model
/// buffer — large enough that the pre-shard fold's agg scatter misses
/// L1/L2 while the sharded fold's slices stay cache-resident.
const DIM: usize = 1 << 18;

/// Pre-PR server fold, replicated verbatim from the coordinator before
/// the shard plan: per-round `Vec<Block>` collect, per-(block, update)
/// `add_range_into` (binary search + scan), blocks cut one per thread.
#[allow(clippy::too_many_arguments)]
fn seed_apply_round_blocked(
    theta: &mut [f64],
    h: &mut [f64],
    agg: &mut [f64],
    stale: &[StaleUpdate],
    updates: &[Option<SparseUpdate>],
    cfg: &GdSecConfig,
    fold_scale: f64,
    pool: &Pool,
) {
    let d = theta.len();
    if d == 0 {
        return;
    }
    struct Block<'a> {
        j0: usize,
        theta: &'a mut [f64],
        h: &'a mut [f64],
        agg: &'a mut [f64],
    }
    let chunk = pool.block_width(d);
    let mut blocks: Vec<Block<'_>> = theta
        .chunks_mut(chunk)
        .zip(h.chunks_mut(chunk))
        .zip(agg.chunks_mut(chunk))
        .enumerate()
        .map(|(b, ((tc, hc), ac))| Block { j0: b * chunk, theta: tc, h: hc, agg: ac })
        .collect();
    pool.scatter(&mut blocks, |_, blk| {
        linalg::zero(blk.agg);
        for s in stale {
            s.update.add_range_into(blk.j0, blk.agg);
        }
        for u in updates.iter().flatten() {
            u.add_range_into(blk.j0, blk.agg);
        }
        if fold_scale != 1.0 {
            for v in blk.agg.iter_mut() {
                *v *= fold_scale;
            }
        }
        if cfg.state_variable {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * (blk.h[j] + blk.agg[j]);
                blk.h[j] += cfg.beta * blk.agg[j];
            }
        } else {
            for j in 0..blk.theta.len() {
                blk.theta[j] -= cfg.alpha * blk.agg[j];
            }
        }
    });
}

/// Pre-PR ledger booking: a post-apply pass over every update's full
/// index list (replicated from the removed `book_shares`).
fn seed_book_shares(
    h_shares: &mut [Vec<f64>],
    bs: f64,
    due: &[StaleUpdate],
    updates: &[Option<SparseUpdate>],
) {
    let mut book_one = |share: &mut [f64], u: &SparseUpdate| {
        for (&ix, &v) in u.idx.iter().zip(u.val.iter()) {
            share[ix as usize] += bs * v as f64;
        }
    };
    for s in due {
        book_one(&mut h_shares[s.worker], &s.update);
    }
    for (w, u) in updates.iter().enumerate() {
        if let Some(u) = u {
            book_one(&mut h_shares[w], u);
        }
    }
}

/// One synthetic provider's admitted update: `nnz` strictly increasing
/// indices spread evenly over `[0, d)` with per-slot jitter (stride
/// sampling keeps every shard populated, like a censored-gradient wire
/// image at this density).
fn synthetic_update(rng: &mut Pcg64, d: usize, nnz: usize) -> SparseUpdate {
    let step = d / nnz;
    assert!(step >= 1, "nnz must divide into d");
    let mut u = SparseUpdate::empty(d);
    for i in 0..nnz {
        u.idx.push((i * step + rng.index(step)) as u32);
        u.val.push((rng.normal() * 1e-6) as f32);
    }
    u
}

/// Static context keys per sweep point (the artifact schema never
/// depends on which mode ran).
fn ups_key(m: usize, nnz: usize) -> &'static str {
    match (m, nnz) {
        (4, 256) => "server_updates_per_sec_m4_nnz256",
        (4, 4096) => "server_updates_per_sec_m4_nnz4096",
        (4, 32768) => "server_updates_per_sec_m4_nnz32768",
        (16, 256) => "server_updates_per_sec_m16_nnz256",
        (16, 4096) => "server_updates_per_sec_m16_nnz4096",
        (16, 32768) => "server_updates_per_sec_m16_nnz32768",
        (64, 256) => "server_updates_per_sec_m64_nnz256",
        (64, 4096) => "server_updates_per_sec_m64_nnz4096",
        (64, 32768) => "server_updates_per_sec_m64_nnz32768",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn mbps_key(m: usize, nnz: usize) -> &'static str {
    match (m, nnz) {
        (4, 256) => "server_mbps_m4_nnz256",
        (4, 4096) => "server_mbps_m4_nnz4096",
        (4, 32768) => "server_mbps_m4_nnz32768",
        (16, 256) => "server_mbps_m16_nnz256",
        (16, 4096) => "server_mbps_m16_nnz4096",
        (16, 32768) => "server_mbps_m16_nnz32768",
        (64, 256) => "server_mbps_m64_nnz256",
        (64, 4096) => "server_mbps_m64_nnz4096",
        (64, 32768) => "server_mbps_m64_nnz32768",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn speedup_key(nnz: usize) -> &'static str {
    match nnz {
        256 => "server_sharded_speedup_m64_nnz256",
        4096 => "server_sharded_speedup_m64_nnz4096",
        32768 => "server_sharded_speedup_m64_nnz32768",
        _ => unreachable!("unexpected sweep point"),
    }
}

/// Admission-cut placement: fold time with the cut pinned to the
/// coordinator thread (pre-PR placement, `ShardPlan::set_serial_cut`)
/// over fold time with the cut fanned out across the pool.
fn cut_key(nnz: usize) -> &'static str {
    match nnz {
        256 => "server_cut_fanout_speedup_m64_nnz256",
        4096 => "server_cut_fanout_speedup_m64_nnz4096",
        32768 => "server_cut_fanout_speedup_m64_nnz32768",
        _ => unreachable!("unexpected sweep point"),
    }
}

fn out_path() -> PathBuf {
    if let Ok(p) = std::env::var("GDSEC_BENCH_OUT") {
        return PathBuf::from(p);
    }
    // rust/ -> repo root
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(&manifest).join("BENCH_server.json")
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let pool = Pool::from_env();
    let cfg = GdSecConfig { alpha: 1e-3, beta: 0.01, ..Default::default() };
    let mut plan = ShardPlan::new();
    plan.ensure(DIM, &pool);
    let mut reports: Vec<BenchStats> = Vec::new();
    let mut context: Vec<(&str, Json)> = vec![
        ("bench", Json::str("server_saturation")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(pool.threads() as f64)),
        ("shards", Json::num(plan.shards() as f64)),
        ("dim", Json::num(DIM as f64)),
    ];

    let mut speedup_product = 1.0f64;
    let mut speedup_points = 0usize;
    for &nnz in &[256usize, 4096, 32768] {
        let mut baseline_mean_ns = None;
        for &m in &[4usize, 16, 64] {
            let mut rng = Pcg64::seeded((m * 1_000_003 + nnz) as u64);
            let updates: Vec<Option<SparseUpdate>> =
                (0..m).map(|_| Some(synthetic_update(&mut rng, DIM, nnz))).collect();
            // Wire bytes absorbed per round: the decoded payload sizes.
            let mut buf = Vec::new();
            let mut round_bytes = 0usize;
            for u in updates.iter().flatten() {
                buf.clear();
                compress::encode_sparse(u, &mut buf);
                round_bytes += buf.len();
            }
            let theta0: Vec<f64> = (0..DIM).map(|_| rng.normal() * 0.01).collect();
            let h0: Vec<f64> = (0..DIM).map(|_| rng.normal() * 1e-3).collect();

            // Bitwise parity gate before any timing: the shard plan must
            // be a pure reorganization of the seed fold's arithmetic.
            {
                let (mut t_a, mut h_a) = (theta0.clone(), h0.clone());
                let mut agg_a = vec![0.0f64; DIM];
                let mut sh_a = vec![vec![0.0f64; DIM]; m];
                seed_apply_round_blocked(
                    &mut t_a, &mut h_a, &mut agg_a, &[], &updates, &cfg, 1.0, &pool,
                );
                seed_book_shares(&mut sh_a, cfg.beta, &[], &updates);
                let (mut t_b, mut h_b) = (theta0.clone(), h0.clone());
                let mut agg_b = vec![0.0f64; DIM];
                let mut sh_b = vec![vec![0.0f64; DIM]; m];
                plan.fold(
                    &pool,
                    updates.iter().enumerate().filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                    ShardApply {
                        theta: &mut t_b,
                        h: &mut h_b,
                        agg: &mut agg_b,
                        theta_prev: None,
                        alpha: cfg.alpha,
                        beta: cfg.beta,
                        state_variable: true,
                        fold_scale: 1.0,
                        staged_agg: false,
                        shares: Some(ShareBook { slabs: &mut sh_b, slot_of: None, scale: cfg.beta }),
                    },
                );
                for j in 0..DIM {
                    assert_eq!(
                        t_a[j].to_bits(),
                        t_b[j].to_bits(),
                        "sharded/seed θ parity broke at {j} (M={m} nnz={nnz})"
                    );
                    assert_eq!(h_a[j].to_bits(), h_b[j].to_bits(), "h parity broke at {j}");
                    assert_eq!(agg_a[j].to_bits(), agg_b[j].to_bits(), "agg parity broke at {j}");
                }
                for w in 0..m {
                    assert_eq!(sh_a[w], sh_b[w], "ledger parity broke at worker {w}");
                }
                // Cut placement is a scheduling choice, never an
                // arithmetic one: the serial-cut fold must match the
                // fanned-cut fold bit for bit.
                let (mut t_c, mut h_c) = (theta0.clone(), h0.clone());
                let mut agg_c = vec![0.0f64; DIM];
                let mut sh_c = vec![vec![0.0f64; DIM]; m];
                plan.set_serial_cut(true);
                plan.fold(
                    &pool,
                    updates.iter().enumerate().filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                    ShardApply {
                        theta: &mut t_c,
                        h: &mut h_c,
                        agg: &mut agg_c,
                        theta_prev: None,
                        alpha: cfg.alpha,
                        beta: cfg.beta,
                        state_variable: true,
                        fold_scale: 1.0,
                        staged_agg: false,
                        shares: Some(ShareBook { slabs: &mut sh_c, slot_of: None, scale: cfg.beta }),
                    },
                );
                plan.set_serial_cut(false);
                for j in 0..DIM {
                    assert_eq!(
                        t_b[j].to_bits(),
                        t_c[j].to_bits(),
                        "serial/fanned cut parity broke at {j} (M={m} nnz={nnz})"
                    );
                }
            }

            // --- sharded fold timing ---
            let mut theta = theta0.clone();
            let mut h = h0.clone();
            let mut agg = vec![0.0f64; DIM];
            let mut h_shares = vec![vec![0.0f64; DIM]; m];
            let stats = b.run_units(
                &format!(
                    "server fold sharded M={m} nnz={nnz} t={} shards={}",
                    pool.threads(),
                    plan.shards()
                ),
                m as f64,
                "upd",
                || {
                    plan.fold(
                        &pool,
                        updates
                            .iter()
                            .enumerate()
                            .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                        ShardApply {
                            theta: &mut theta,
                            h: &mut h,
                            agg: &mut agg,
                            theta_prev: None,
                            alpha: cfg.alpha,
                            beta: cfg.beta,
                            state_variable: true,
                            fold_scale: 1.0,
                            staged_agg: false,
                            shares: Some(ShareBook {
                                slabs: &mut h_shares,
                                slot_of: None,
                                scale: cfg.beta,
                            }),
                        },
                    );
                    std::hint::black_box(theta[0]);
                },
            );
            context.push((ups_key(m, nnz), Json::num(stats.throughput().unwrap_or(0.0))));
            context.push((
                mbps_key(m, nnz),
                Json::num(round_bytes as f64 / 1e6 / (stats.mean_ns * 1e-9)),
            ));

            // --- seed baseline at the saturation point (M = 64) ---
            if m == 64 {
                let mut theta_s = theta0.clone();
                let mut h_s = h0.clone();
                let mut agg_s = vec![0.0f64; DIM];
                let mut sh_s = vec![vec![0.0f64; DIM]; m];
                let seed_stats = b.run_units(
                    &format!("server fold seed-baseline M={m} nnz={nnz} t={}", pool.threads()),
                    m as f64,
                    "upd",
                    || {
                        seed_apply_round_blocked(
                            &mut theta_s,
                            &mut h_s,
                            &mut agg_s,
                            &[],
                            &updates,
                            &cfg,
                            1.0,
                            &pool,
                        );
                        seed_book_shares(&mut sh_s, cfg.beta, &[], &updates);
                        std::hint::black_box(theta_s[0]);
                    },
                );
                let speedup = seed_stats.mean_ns / stats.mean_ns;
                context.push((speedup_key(nnz), Json::num(speedup)));
                speedup_product *= speedup;
                speedup_points += 1;
                baseline_mean_ns = Some(seed_stats.mean_ns);
                reports.push(seed_stats);

                // --- admission cut on the coordinator thread (pre-PR
                //     placement) vs the pooled fan-out ---
                let mut theta_c = theta0.clone();
                let mut h_c = h0.clone();
                let mut agg_c = vec![0.0f64; DIM];
                let mut sh_c = vec![vec![0.0f64; DIM]; m];
                plan.set_serial_cut(true);
                let cut_stats = b.run_units(
                    &format!("server fold serial-cut M={m} nnz={nnz} t={}", pool.threads()),
                    m as f64,
                    "upd",
                    || {
                        plan.fold(
                            &pool,
                            updates
                                .iter()
                                .enumerate()
                                .filter_map(|(w, u)| u.as_ref().map(|u| (w, u))),
                            ShardApply {
                                theta: &mut theta_c,
                                h: &mut h_c,
                                agg: &mut agg_c,
                                theta_prev: None,
                                alpha: cfg.alpha,
                                beta: cfg.beta,
                                state_variable: true,
                                fold_scale: 1.0,
                                staged_agg: false,
                                shares: Some(ShareBook {
                                    slabs: &mut sh_c,
                                    slot_of: None,
                                    scale: cfg.beta,
                                }),
                            },
                        );
                        std::hint::black_box(theta_c[0]);
                    },
                );
                plan.set_serial_cut(false);
                context.push((cut_key(nnz), Json::num(cut_stats.mean_ns / stats.mean_ns)));
                reports.push(cut_stats);
            }
            reports.push(stats);
        }
        if let Some(ns) = baseline_mean_ns {
            println!("seed baseline M=64 nnz={nnz}: {}", bench::fmt_ns(ns));
        }
    }
    context.push((
        "server_sharded_speedup_m64",
        Json::num(speedup_product.powf(1.0 / speedup_points.max(1) as f64)),
    ));

    println!("\n== server saturation ==");
    for r in &reports {
        println!("{}", r.report());
    }
    for (k, v) in &context {
        if let Some(x) = v.as_f64() {
            println!("{k}: {x:.2}");
        }
    }
    let path = out_path();
    match bench::write_json(&path, context, &reports) {
        Ok(()) => println!("bench artifact -> {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
