//! Hot-path microbenchmarks (custom harness — no criterion offline).
//!
//! Covers every operation on the per-round critical path:
//!   worker: gradient (gemv / fused pass), sparsify (censor+EC), RLE
//!   server: decode, aggregate, apply_round
//!   codecs: QSGD quantize/dequantize, protocol frame encode/decode
//! plus "seed-baseline" replicas of the pre-optimization scalar kernels,
//! so each run reports the blocked/unrolled kernels' speedup, and an
//! end-to-end serial-vs-parallel GD-SEC run at fig1 scale.
//!
//! Results are printed AND written to `BENCH_hotpath.json` at the repo
//! root (override with `GDSEC_BENCH_OUT`), schema `gdsec-bench-v1` — the
//! PR-over-PR perf trajectory behind EXPERIMENTS.md §Perf. Set
//! `GDSEC_BENCH_QUICK=1` for the CI smoke run.

use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::gdsec::{GdSecConfig, ServerState, WorkerState, Xi};
use gdsec::compress::{self, quantize, rle, SparseUpdate};
use gdsec::coordinator::protocol::{self, Msg};
use gdsec::data::{synthetic, Features};
use gdsec::linalg::{self, DenseMat};
use gdsec::objectives::Problem;
use gdsec::util::bench::{self, BenchStats, Bencher};
use gdsec::util::cache;
use gdsec::util::json::Json;
use gdsec::util::pool::Pool;
use gdsec::util::rng::Pcg64;
use std::path::PathBuf;

/// The seed's scalar axpy (indexed loop, bounds checks intact) — kept as
/// the baseline the blocked kernels are measured against.
fn seed_axpy(a: f64, x: &[f64], y: &mut [f64]) {
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// The seed's row-streaming transposed GEMV: one full-length axpy over
/// the d-wide accumulator per row.
fn seed_gemv_t_acc(m: &DenseMat, alpha: f64, r: &[f64], out: &mut [f64]) {
    for i in 0..m.rows {
        let a = alpha * r[i];
        if a != 0.0 {
            seed_axpy(a, m.row(i), out);
        }
    }
}

/// The seed codec's per-value byte pushes (vs the bulk-copied f32 value
/// plane `compress::encode_sparse` writes now). Wire bytes are identical.
fn seed_encode_sparse(u: &SparseUpdate, out: &mut Vec<u8>) {
    rle::put_varint(out, u.idx.len() as u32);
    rle::encode_gaps(&u.idx, out);
    for &v in &u.val {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// The seed pool's per-round scoped-spawn fan-out (replica of the
/// pre-persistent `Pool::scatter`), with the same per-lane work as the
/// persistent round-trip bench.
fn seed_scoped_scatter(items: &mut [u64], threads: usize) {
    let n = items.len();
    if threads == 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            *item = item.wrapping_add(i as u64);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (ci, ch) in items.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (j, item) in ch.iter_mut().enumerate() {
                    *item = item.wrapping_add((ci * chunk + j) as u64);
                }
            });
        }
    });
}

/// The seed's 4-accumulator dot product.
fn seed_dot(x: &[f64], y: &[f64]) -> f64 {
    let n = x.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += x[i] * y[i];
        s1 += x[i + 1] * y[i + 1];
        s2 += x[i + 2] * y[i + 2];
        s3 += x[i + 3] * y[i + 3];
    }
    let mut tail = 0.0;
    for i in chunks * 4..n {
        tail += x[i] * y[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

// ---------------------------------------------------------------------
// Verbatim replicas of the kernels as they stood immediately before the
// fixed-lane rewrite (8 independent chains / zip loops, autovectorized by
// LLVM at the SSE2 baseline). The rewritten dispatch kernels must match
// these bitwise — asserted before any timing — and the per-kernel
// `*_speedup_vs_prepr` keys measure what the rewrite (lane-structured
// scalar + optional AVX path) buys over them.
// ---------------------------------------------------------------------

/// Pre-rewrite axpy: zip loop, LLVM-autovectorized.
fn prepr_axpy(a: f64, x: &[f64], y: &mut [f64]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// Pre-rewrite dot: 8 independent chains, fixed pairwise combine.
fn prepr_dot(x: &[f64], y: &[f64]) -> f64 {
    let mut s = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let yc = y.chunks_exact(8);
    let (xr, yr) = (xc.remainder(), yc.remainder());
    for (a, b) in xc.zip(yc) {
        for j in 0..8 {
            s[j] += a[j] * b[j];
        }
    }
    let mut tail = 0.0;
    for (a, b) in xr.iter().zip(yr) {
        tail += a * b;
    }
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail
}

/// Pre-rewrite dot2: two rows against a shared `x` stream.
fn prepr_dot2(r0: &[f64], r1: &[f64], x: &[f64]) -> (f64, f64) {
    let mut s = [0.0f64; 8];
    let mut t = [0.0f64; 8];
    let xc = x.chunks_exact(8);
    let r0c = r0.chunks_exact(8);
    let r1c = r1.chunks_exact(8);
    let (xr, r0r, r1r) = (xc.remainder(), r0c.remainder(), r1c.remainder());
    for ((b, a0), a1) in xc.zip(r0c).zip(r1c) {
        for j in 0..8 {
            s[j] += a0[j] * b[j];
            t[j] += a1[j] * b[j];
        }
    }
    let (mut tail0, mut tail1) = (0.0, 0.0);
    for (k, &b) in xr.iter().enumerate() {
        tail0 += r0r[k] * b;
        tail1 += r1r[k] * b;
    }
    (
        ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7])) + tail0,
        ((t[0] + t[1]) + (t[2] + t[3])) + ((t[4] + t[5]) + (t[6] + t[7])) + tail1,
    )
}

/// Pre-rewrite sub: zip loop.
fn prepr_sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    for (o, (&a, &b)) in out.iter_mut().zip(x.iter().zip(y)) {
        *o = a - b;
    }
}

/// Pre-rewrite fused sub + |·|max: single sequential running max.
fn prepr_sub_abs_max(x: &[f64], y: &[f64], out: &mut [f64]) -> f64 {
    let mut m = 0.0f64;
    for (o, (&a, &b)) in out.iter_mut().zip(x.iter().zip(y)) {
        let v = a - b;
        *o = v;
        m = m.max(v.abs());
    }
    m
}

/// Pre-rewrite gemv: row pairs through [`prepr_dot2`], odd row via dot.
fn prepr_gemv(m: &DenseMat, x: &[f64], out: &mut [f64]) {
    let mut i = 0;
    while i + 2 <= m.rows {
        let (d0, d1) = prepr_dot2(m.row(i), m.row(i + 1), x);
        out[i] = d0;
        out[i + 1] = d1;
        i += 2;
    }
    if i < m.rows {
        out[i] = prepr_dot(m.row(i), x);
    }
}

/// Pre-rewrite gemv_t_acc: fixed 1024-column blocks + zip axpy.
fn prepr_gemv_t_acc(m: &DenseMat, alpha: f64, r: &[f64], out: &mut [f64]) {
    const COL_BLOCK: usize = 1024;
    let cols = m.cols;
    let mut j0 = 0;
    while j0 < cols {
        let j1 = (j0 + COL_BLOCK).min(cols);
        let ob = &mut out[j0..j1];
        for i in 0..m.rows {
            let a = alpha * r[i];
            if a != 0.0 {
                let row = &m.data[i * cols + j0..i * cols + j1];
                prepr_axpy(a, row, ob);
            }
        }
        j0 = j1;
    }
}

fn out_path() -> PathBuf {
    if let Ok(p) = std::env::var("GDSEC_BENCH_OUT") {
        return PathBuf::from(p);
    }
    // rust/ -> repo root
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(&manifest).join("BENCH_hotpath.json")
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("GDSEC_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut reports: Vec<BenchStats> = Vec::new();
    // The persistent pool every parallel section below fans out over.
    let par_pool = Pool::from_env();
    let mut context: Vec<(&str, Json)> = vec![
        ("bench", Json::str("hotpath_micro")),
        ("quick", Json::Bool(quick)),
        ("threads", Json::num(par_pool.threads() as f64)),
        // Which kernel path this run measured, and the cache model the
        // block trees were derived from (EXPERIMENTS.md §Cache model).
        ("simd_active", Json::Bool(linalg::simd_active())),
        ("cache_l1d_bytes", Json::num(cache::model().l1d_bytes as f64)),
        ("cache_l2_bytes", Json::num(cache::model().l2_bytes as f64)),
        ("nnz_budget_auto", Json::num(cache::auto_nnz_budget() as f64)),
    ];

    // --- sparsify at the paper's dimensions (reused buffer = hot path) ---
    for &d in &[784usize, 3072, 47236] {
        let mut rng = Pcg64::seeded(d as u64);
        let mut ws = WorkerState::new(d);
        let grad: Vec<f64> = (0..d).map(|_| rng.normal() * 0.1).collect();
        let diff: Vec<f64> = (0..d).map(|_| rng.normal() * 1e-3).collect();
        let cfg = GdSecConfig { xi: Xi::Uniform(100.0), beta: 0.01, ..Default::default() };
        let mut up = SparseUpdate::empty(d);
        reports.push(b.run_units(&format!("sparsify_into d={d}"), d as f64, "elem", || {
            ws.grad_mut().copy_from_slice(&grad);
            ws.sparsify_into(&cfg, 5, &diff, &mut up);
            std::hint::black_box(up.nnz());
        }));
    }

    // --- gradient (the worker's other half) ---
    let prob = Problem::linear(synthetic::mnist_like(1, 400), 1, 1e-3);
    let l = &prob.locals[0];
    let theta = vec![0.01; prob.d];
    let mut g = vec![0.0; prob.d];
    let elems = (400 * prob.d) as f64;
    reports.push(b.run_units("local grad linreg 400x784", elems, "madd", || {
        l.grad(&theta, &mut g);
        std::hint::black_box(g[0]);
    }));

    // --- blocked linalg kernels at RCV1 scale, vs the seed baselines ---
    let (rows, d) = (if quick { 32 } else { 96 }, 47236usize);
    let mut rng = Pcg64::seeded(47);
    let a = DenseMat {
        rows,
        cols: d,
        data: (0..rows * d).map(|_| rng.normal()).collect(),
    };
    let x47: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let r47: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let mut out_d = vec![0.0; d];
    let mut out_r = vec![0.0; rows];
    let madds = (rows * d) as f64;

    let gemv_t_new = b.run_units(&format!("gemv_t_acc {rows}x{d} blocked"), madds, "madd", || {
        linalg::zero(&mut out_d);
        a.gemv_t_acc(1.0, &r47, &mut out_d);
        std::hint::black_box(out_d[0]);
    });
    let gemv_t_seed =
        b.run_units(&format!("gemv_t_acc {rows}x{d} seed-baseline"), madds, "madd", || {
            linalg::zero(&mut out_d);
            seed_gemv_t_acc(&a, 1.0, &r47, &mut out_d);
            std::hint::black_box(out_d[0]);
        });
    context.push((
        "gemv_t_acc_47236_speedup_vs_seed",
        Json::num(gemv_t_seed.mean_ns / gemv_t_new.mean_ns),
    ));
    reports.push(gemv_t_new);
    reports.push(gemv_t_seed);

    let gemv_new = b.run_units(&format!("gemv {rows}x{d} row-paired"), madds, "madd", || {
        a.gemv(&x47, &mut out_r);
        std::hint::black_box(out_r[0]);
    });
    let gemv_seed = b.run_units(&format!("gemv {rows}x{d} seed-baseline"), madds, "madd", || {
        for i in 0..a.rows {
            out_r[i] = seed_dot(a.row(i), &x47);
        }
        std::hint::black_box(out_r[0]);
    });
    context.push(("gemv_47236_speedup_vs_seed", Json::num(gemv_seed.mean_ns / gemv_new.mean_ns)));
    reports.push(gemv_new);
    reports.push(gemv_seed);

    let dot_new = b.run_units("dot 47236 8-wide", d as f64, "madd", || {
        std::hint::black_box(linalg::dot(&x47, &x47));
    });
    let dot_seed = b.run_units("dot 47236 seed-baseline", d as f64, "madd", || {
        std::hint::black_box(seed_dot(&x47, &x47));
    });
    context.push(("dot_47236_speedup_vs_seed", Json::num(dot_seed.mean_ns / dot_new.mean_ns)));
    reports.push(dot_new);
    reports.push(dot_seed);

    // --- fixed-lane kernels vs verbatim pre-rewrite replicas. d=2048
    //     keeps every operand L1/L2-resident so the timing isolates the
    //     kernel, not DRAM bandwidth. The dispatch path (scalar lanes,
    //     or AVX when built with `--features simd` on a capable CPU)
    //     must stay bitwise identical to the pre-rewrite kernels —
    //     asserted across tail remainders before any timing. ---
    {
        let n = 2048usize;
        let lrows = 64usize;
        let mut rng = Pcg64::seeded(71);
        let xv: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let yv: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let lm = DenseMat {
            rows: lrows,
            cols: n,
            data: (0..lrows * n).map(|_| rng.normal()).collect(),
        };
        let rv: Vec<f64> = (0..lrows).map(|_| rng.normal()).collect();
        let mut out_a = vec![0.0; n];
        let mut out_b = vec![0.0; n];
        let mut outr_a = vec![0.0; lrows];
        let mut outr_b = vec![0.0; lrows];

        // Bitwise parity before timing, covering the 8-chunk body plus
        // both tail shapes (mod 8 and mod 4 remainders).
        for len in [n, n - 3, n - 5, 17, 4, 1, 0] {
            let (x, y) = (&xv[..len], &yv[..len]);
            assert_eq!(
                linalg::dot(x, y).to_bits(),
                prepr_dot(x, y).to_bits(),
                "dot dispatch/pre-rewrite parity broke at len={len}"
            );
            let (n0, n1) = linalg::dot2(x, y, x);
            let (p0, p1) = prepr_dot2(x, y, x);
            assert_eq!((n0.to_bits(), n1.to_bits()), (p0.to_bits(), p1.to_bits()));
            out_a[..len].copy_from_slice(y);
            out_b[..len].copy_from_slice(y);
            linalg::axpy(0.37, x, &mut out_a[..len]);
            prepr_axpy(0.37, x, &mut out_b[..len]);
            let mut sm_a = vec![0.0; len];
            let mut sm_b = vec![0.0; len];
            linalg::sub(x, y, &mut sm_a);
            prepr_sub(x, y, &mut sm_b);
            let ma = linalg::sub_abs_max(x, y, &mut out_a[..len]);
            let mb = prepr_sub_abs_max(x, y, &mut out_b[..len]);
            assert_eq!(ma.to_bits(), mb.to_bits());
            for j in 0..len {
                assert_eq!(sm_a[j].to_bits(), sm_b[j].to_bits());
                assert_eq!(out_a[j].to_bits(), out_b[j].to_bits());
            }
        }
        lm.gemv(&xv, &mut outr_a);
        prepr_gemv(&lm, &xv, &mut outr_b);
        for i in 0..lrows {
            assert_eq!(outr_a[i].to_bits(), outr_b[i].to_bits(), "gemv parity broke");
        }
        linalg::zero(&mut out_a);
        linalg::zero(&mut out_b);
        lm.gemv_t_acc(1.0, &rv, &mut out_a);
        prepr_gemv_t_acc(&lm, 1.0, &rv, &mut out_b);
        for j in 0..n {
            assert_eq!(out_a[j].to_bits(), out_b[j].to_bits(), "gemv_t_acc parity broke");
        }

        // Timed pairs. Each key is new-kernel speedup over its verbatim
        // pre-rewrite replica; the geomean is the PR's headline number.
        let mut lane_ratios: Vec<f64> = Vec::new();
        fn push_pair(
            key: &'static str,
            new: BenchStats,
            old: BenchStats,
            context: &mut Vec<(&str, Json)>,
            reports: &mut Vec<BenchStats>,
            ratios: &mut Vec<f64>,
        ) {
            let ratio = old.mean_ns / new.mean_ns;
            context.push((key, Json::num(ratio)));
            ratios.push(ratio);
            reports.push(new);
            reports.push(old);
        }

        let k_new = b.run_units("dot 2048 lane-dispatch", n as f64, "madd", || {
            std::hint::black_box(linalg::dot(&xv, &yv));
        });
        let k_old = b.run_units("dot 2048 pre-rewrite", n as f64, "madd", || {
            std::hint::black_box(prepr_dot(&xv, &yv));
        });
        push_pair(
            "dot_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let k_new = b.run_units("dot2 2048 lane-dispatch", 2.0 * n as f64, "madd", || {
            std::hint::black_box(linalg::dot2(&xv, &yv, &xv));
        });
        let k_old = b.run_units("dot2 2048 pre-rewrite", 2.0 * n as f64, "madd", || {
            std::hint::black_box(prepr_dot2(&xv, &yv, &xv));
        });
        push_pair(
            "dot2_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let k_new = b.run_units("axpy 2048 lane-dispatch", n as f64, "madd", || {
            linalg::axpy(1e-9, &xv, &mut out_a);
            std::hint::black_box(out_a[0]);
        });
        let k_old = b.run_units("axpy 2048 pre-rewrite", n as f64, "madd", || {
            prepr_axpy(1e-9, &xv, &mut out_b);
            std::hint::black_box(out_b[0]);
        });
        push_pair(
            "axpy_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let k_new = b.run_units("sub 2048 lane-dispatch", n as f64, "elem", || {
            linalg::sub(&xv, &yv, &mut out_a);
            std::hint::black_box(out_a[0]);
        });
        let k_old = b.run_units("sub 2048 pre-rewrite", n as f64, "elem", || {
            prepr_sub(&xv, &yv, &mut out_b);
            std::hint::black_box(out_b[0]);
        });
        push_pair(
            "sub_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let k_new = b.run_units("sub_abs_max 2048 lane-dispatch", n as f64, "elem", || {
            std::hint::black_box(linalg::sub_abs_max(&xv, &yv, &mut out_a));
        });
        let k_old = b.run_units("sub_abs_max 2048 pre-rewrite", n as f64, "elem", || {
            std::hint::black_box(prepr_sub_abs_max(&xv, &yv, &mut out_b));
        });
        push_pair(
            "sub_abs_max_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let madds2 = (lrows * n) as f64;
        let k_new = b.run_units("gemv 64x2048 lane-dispatch", madds2, "madd", || {
            lm.gemv(&xv, &mut outr_a);
            std::hint::black_box(outr_a[0]);
        });
        let k_old = b.run_units("gemv 64x2048 pre-rewrite", madds2, "madd", || {
            prepr_gemv(&lm, &xv, &mut outr_b);
            std::hint::black_box(outr_b[0]);
        });
        push_pair(
            "gemv_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let k_new = b.run_units("gemv_t_acc 64x2048 lane-dispatch", madds2, "madd", || {
            linalg::zero(&mut out_a);
            lm.gemv_t_acc(1.0, &rv, &mut out_a);
            std::hint::black_box(out_a[0]);
        });
        let k_old = b.run_units("gemv_t_acc 64x2048 pre-rewrite", madds2, "madd", || {
            linalg::zero(&mut out_b);
            prepr_gemv_t_acc(&lm, 1.0, &rv, &mut out_b);
            std::hint::black_box(out_b[0]);
        });
        push_pair(
            "gemv_t_acc_2048_speedup_vs_prepr",
            k_new,
            k_old,
            &mut context,
            &mut reports,
            &mut lane_ratios,
        );

        let geo = (lane_ratios.iter().map(|r| r.ln()).sum::<f64>()
            / lane_ratios.len() as f64)
            .exp();
        context.push(("lane_kernel_geomean_speedup_vs_prepr", Json::num(geo)));
    }

    // --- fused server-side helpers ---
    let y47: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    reports.push(b.run_units("sub_abs_max 47236 fused", d as f64, "elem", || {
        std::hint::black_box(linalg::sub_abs_max(&x47, &y47, &mut out_d));
    }));

    // --- column-blocked CSR AᵀSpMV at RCV1 scale vs the seed's scalar
    //     walk (the Fig 7 sparse hot path) ---
    let sp_rows = if quick { 2000 } else { 15181 };
    let sp_data = synthetic::rcv1_like(47, sp_rows, 47236, 50);
    let a_sp = match &sp_data.x {
        Features::Sparse(m) => m,
        Features::Dense(_) => panic!("rcv1_like must be sparse"),
    };
    let mut rng = Pcg64::seeded(53);
    let r_sp: Vec<f64> = (0..a_sp.rows).map(|_| rng.normal()).collect();
    let mut out_sp = vec![0.0; a_sp.cols];
    // Parity check once before timing: pooled must equal serial bitwise.
    {
        let mut serial = vec![0.0; a_sp.cols];
        a_sp.spmv_t_acc(1.0, &r_sp, &mut serial);
        a_sp.spmv_t_acc_pooled(1.0, &r_sp, &mut out_sp, &par_pool);
        for j in 0..a_sp.cols {
            assert_eq!(
                serial[j].to_bits(),
                out_sp[j].to_bits(),
                "spmv_t_acc pooled/serial parity broke at {j}"
            );
        }
    }
    let spmv_nnz = a_sp.nnz() as f64;
    let spmv_new = b.run_units(
        &format!("spmv_t_acc {sp_rows}x47236 pooled t={}", par_pool.threads()),
        spmv_nnz,
        "nnz",
        || {
            linalg::zero(&mut out_sp);
            a_sp.spmv_t_acc_pooled(1.0, &r_sp, &mut out_sp, &par_pool);
            std::hint::black_box(out_sp[0]);
        },
    );
    let spmv_seed = b.run_units(
        &format!("spmv_t_acc {sp_rows}x47236 seed-baseline"),
        spmv_nnz,
        "nnz",
        || {
            linalg::zero(&mut out_sp);
            a_sp.spmv_t_acc(1.0, &r_sp, &mut out_sp);
            std::hint::black_box(out_sp[0]);
        },
    );
    context.push((
        "spmv_t_acc_47236_speedup_vs_seed",
        Json::num(spmv_seed.mean_ns / spmv_new.mean_ns),
    ));
    reports.push(spmv_new);
    reports.push(spmv_seed);

    // --- RLE codec ---
    let mut rng = Pcg64::seeded(9);
    for &(d, p_zero) in &[(784usize, 0.5), (47236, 0.95)] {
        let v: Vec<f64> =
            (0..d).map(|_| if rng.bernoulli(p_zero) { 0.0 } else { rng.normal() }).collect();
        let up = SparseUpdate::from_dense(&v);
        let mut buf = Vec::with_capacity(8 * d);
        reports.push(b.run_units(
            &format!("rle encode d={d} nnz={}", up.nnz()),
            up.nnz() as f64,
            "entry",
            || {
                buf.clear();
                compress::encode_sparse(&up, &mut buf);
                std::hint::black_box(buf.len());
            },
        ));
        compress::encode_sparse(&up, &mut buf);
        reports.push(b.run_units(
            &format!("rle decode d={d} nnz={}", up.nnz()),
            up.nnz() as f64,
            "entry",
            || {
                let (u, _) = compress::decode_sparse(&buf, d as u32).unwrap();
                std::hint::black_box(u.nnz());
            },
        ));
    }

    // --- bulk f32 value plane vs the seed's per-value byte pushes ---
    let d_wire = 47236usize;
    let mut rng = Pcg64::seeded(29);
    let v: Vec<f64> =
        (0..d_wire).map(|_| if rng.bernoulli(0.5) { 0.0 } else { rng.normal() }).collect();
    let wire_up = SparseUpdate::from_dense(&v);
    let mut buf_new = Vec::with_capacity(8 * d_wire);
    let mut buf_seed = Vec::with_capacity(8 * d_wire);
    // The optimized encoder must stay byte-identical to the seed codec.
    compress::encode_sparse(&wire_up, &mut buf_new);
    seed_encode_sparse(&wire_up, &mut buf_seed);
    assert_eq!(buf_new, buf_seed, "bulk codec changed the wire format");
    let enc_new = b.run_units(
        &format!("encode_sparse d={d_wire} nnz={} bulk", wire_up.nnz()),
        wire_up.nnz() as f64,
        "entry",
        || {
            buf_new.clear();
            compress::encode_sparse(&wire_up, &mut buf_new);
            std::hint::black_box(buf_new.len());
        },
    );
    let enc_seed = b.run_units(
        &format!("encode_sparse d={d_wire} nnz={} seed-baseline", wire_up.nnz()),
        wire_up.nnz() as f64,
        "entry",
        || {
            buf_seed.clear();
            seed_encode_sparse(&wire_up, &mut buf_seed);
            std::hint::black_box(buf_seed.len());
        },
    );
    context.push((
        "encode_sparse_speedup_vs_seed",
        Json::num(enc_seed.mean_ns / enc_new.mean_ns),
    ));
    reports.push(enc_new);
    reports.push(enc_seed);

    // --- pool round-trip latency: persistent (parked workers + barrier)
    //     vs the seed's per-round scoped spawns ---
    {
        let threads = par_pool.threads();
        let mut lanes = vec![0u64; threads.max(2)];
        let rt_new = b.run("pool roundtrip persistent", || {
            par_pool.scatter(&mut lanes, |i, v| *v = v.wrapping_add(i as u64));
            std::hint::black_box(lanes[0]);
        });
        let rt_seed = b.run("pool roundtrip scoped-spawn seed-baseline", || {
            seed_scoped_scatter(&mut lanes, threads);
            std::hint::black_box(lanes[0]);
        });
        context.push(("pool_roundtrip_ns", Json::num(rt_new.mean_ns)));
        context.push((
            "pool_roundtrip_speedup_vs_seed",
            Json::num(rt_seed.mean_ns / rt_new.mean_ns),
        ));
        reports.push(rt_new);
        reports.push(rt_seed);
    }

    // --- QSGD quantizer ---
    let v: Vec<f64> = (0..3072).map(|_| rng.normal()).collect();
    reports.push(b.run_units("qsgd quantize d=3072", 3072.0, "elem", || {
        let q = quantize::quantize(&v, 255, &mut rng);
        std::hint::black_box(q.idx.len());
    }));

    // --- server aggregate + apply (fused, agg re-zeroed in-pass) ---
    let d = 3072;
    let mut server = ServerState::new(d);
    let updates: Vec<SparseUpdate> = (0..100)
        .map(|w| {
            let vv: Vec<f64> =
                (0..d).map(|i| if (i + w) % 10 == 0 { 0.5 } else { 0.0 }).collect();
            SparseUpdate::from_dense(&vv)
        })
        .collect();
    let cfg = GdSecConfig { alpha: 1e-3, beta: 0.01, ..Default::default() };
    reports.push(b.run_units("server apply_round M=100 d=3072", 100.0, "update", || {
        server.apply_round(&cfg, &updates);
        std::hint::black_box(server.theta[0]);
    }));

    // --- protocol framing ---
    let v: Vec<f64> = (0..784).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let up = SparseUpdate::from_dense(&v);
    let msg = Msg::Update { round: 5, worker: 2, update: up, local_f: 0.25 };
    reports.push(b.run("protocol encode+decode update d=784", || {
        let buf = protocol::encode(&msg, 784);
        let m = protocol::decode(&buf, 784).unwrap();
        std::hint::black_box(matches!(m, Msg::Update { .. }));
    }));

    // --- end-to-end: serial vs pooled GD-SEC at fig1 scale, M=8 ---
    let m_workers = 8;
    let e2e_iters = if quick { 8 } else { 60 };
    let prob = Problem::linear(synthetic::mnist_like(3, 2000), m_workers, 1.0 / 2000.0);
    let e2e_cfg = GdSecConfig {
        alpha: 1.0 / prob.lipschitz(),
        beta: 0.01,
        xi: Xi::Uniform(200.0 * m_workers as f64),
        fstar: Some(0.0),
        eval_every: 10,
        ..Default::default()
    };
    // Warm caches/page tables once before the timed runs.
    let _ = gdsec_algo::run_scheduled_pooled(&prob, &e2e_cfg, 2, |_k| None, &par_pool);
    let mut serial_trace = None;
    let e2e_serial = b.run_once(
        &format!("e2e gdsec fig1-scale M={m_workers} iters={e2e_iters} threads=1"),
        || {
            let pool1 = Pool::new(1);
            serial_trace = Some(gdsec_algo::run_scheduled_pooled(
                &prob, &e2e_cfg, e2e_iters, |_k| None, &pool1,
            ));
        },
    );
    let mut par_trace = None;
    let e2e_par = b.run_once(
        &format!(
            "e2e gdsec fig1-scale M={m_workers} iters={e2e_iters} threads={}",
            par_pool.threads()
        ),
        || {
            par_trace = Some(gdsec_algo::run_scheduled_pooled(
                &prob, &e2e_cfg, e2e_iters, |_k| None, &par_pool,
            ));
        },
    );
    let (st, pt) = (serial_trace.unwrap(), par_trace.unwrap());
    assert_eq!(st.total_bits(), pt.total_bits(), "serial/parallel bit parity broke");
    assert_eq!(
        st.rows.last().unwrap().fval.to_bits(),
        pt.rows.last().unwrap().fval.to_bits(),
        "serial/parallel trajectory parity broke"
    );
    context.push((
        "e2e_gdsec_speedup_parallel",
        Json::num(e2e_serial.mean_ns / e2e_par.mean_ns),
    ));
    reports.push(e2e_serial);
    reports.push(e2e_par);

    // --- nested engine lanes: M=2 workers on a 4-thread pool. With only
    //     two shards the per-worker fan-out alone could use 2 cores; the
    //     engine's (worker, row-block) nnz-budget lanes (default budget ⇒
    //     ~24 blocks/worker at this scale) are what let 4 threads bite.
    //     Gated in CI: the 4-thread run must not be slower than 1-thread.
    {
        let m2_iters = if quick { 6 } else { 40 };
        let prob2 = Problem::linear(synthetic::mnist_like(7, 4000), 2, 1.0 / 4000.0);
        let m2_cfg = GdSecConfig {
            alpha: 1.0 / prob2.lipschitz(),
            beta: 0.01,
            xi: Xi::Uniform(200.0 * 2.0),
            fstar: Some(0.0),
            eval_every: 10,
            ..Default::default()
        };
        let pool1 = Pool::new(1);
        let pool4 = Pool::new(4);
        // Parity check once before timing: the nested block tree is fixed
        // by (problem, budget), so thread count must not change a bit.
        let t1 = gdsec_algo::run_scheduled_pooled(&prob2, &m2_cfg, m2_iters, |_k| None, &pool1);
        let t4 = gdsec_algo::run_scheduled_pooled(&prob2, &m2_cfg, m2_iters, |_k| None, &pool4);
        assert_eq!(t1.total_bits(), t4.total_bits(), "nested M=2 bit parity broke");
        assert_eq!(
            t1.rows.last().unwrap().fval.to_bits(),
            t4.rows.last().unwrap().fval.to_bits(),
            "nested M=2 trajectory parity broke"
        );
        // Multi-sample timings (the CI gate floor is 1.0, so the ratio
        // uses medians — robust to a single scheduler hiccup, unlike the
        // one-shot e2e numbers above).
        let nested_serial =
            b.run(&format!("engine nested M=2 iters={m2_iters} threads=1"), || {
                std::hint::black_box(gdsec_algo::run_scheduled_pooled(
                    &prob2, &m2_cfg, m2_iters, |_k| None, &pool1,
                ));
            });
        let nested_par =
            b.run(&format!("engine nested M=2 iters={m2_iters} threads=4"), || {
                std::hint::black_box(gdsec_algo::run_scheduled_pooled(
                    &prob2, &m2_cfg, m2_iters, |_k| None, &pool4,
                ));
            });
        context.push((
            "engine_nested_speedup_m2",
            Json::num(nested_serial.median_ns / nested_par.median_ns),
        ));
        reports.push(nested_serial);
        reports.push(nested_par);
    }

    // --- GDSEC_NNZ_BUDGET sweep at RCV1 scale: the nested-lane budget's
    //     first sparse-data point. Same problem, same pool, three block
    //     trees (16k/64k/256k nnz per lane) — per-round time tells
    //     whether the fixed 64k default should become cache-sized.
    //     Trajectories are budget-dependent but thread-count-invariant;
    //     timing is the only axis here. Gated for PRESENCE in CI. ---
    {
        use gdsec::algo::engine::EngineOpts;
        let rows = if quick { 3000 } else { 12000 };
        let ds = synthetic::rcv1_like(99, rows, 47236, 50);
        let prob_b = Problem::linear(ds, 4, 1e-4);
        let cfg_b = GdSecConfig {
            alpha: 1e-3,
            beta: 0.01,
            xi: Xi::Uniform(50.0),
            fstar: Some(0.0),
            eval_every: 1_000_000, // timing only: skip per-round evals
            ..Default::default()
        };
        let sweep_iters = if quick { 3 } else { 10 };
        // Parity before timing: `EngineOpts::default()` and the
        // GDSEC_NNZ_BUDGET=auto resolution must derive the same budget
        // from the same cache model — identical block tree, identical
        // trajectory, bit for bit.
        {
            let auto_opts =
                EngineOpts { nnz_budget: cache::auto_nnz_budget(), ..EngineOpts::default() };
            let def_opts = EngineOpts::default();
            let r_def =
                gdsec_algo::run_states_opts(&prob_b, &cfg_b, 2, |_k| None, &par_pool, &def_opts);
            let r_auto =
                gdsec_algo::run_states_opts(&prob_b, &cfg_b, 2, |_k| None, &par_pool, &auto_opts);
            for (td, ta) in r_def.server.theta.iter().zip(r_auto.server.theta.iter()) {
                assert_eq!(td.to_bits(), ta.to_bits(), "default/auto budget parity broke");
            }
        }
        let auto_budget = cache::auto_nnz_budget();
        for (budget, key) in [
            (16_384usize, "engine_budget_sweep_ns_16384"),
            (65_536, "engine_budget_sweep_ns_65536"),
            (262_144, "engine_budget_sweep_ns_262144"),
            (auto_budget, "engine_budget_sweep_ns_auto"),
        ] {
            let opts = EngineOpts { nnz_budget: budget, ..EngineOpts::default() };
            let stats = b.run_once(
                &format!(
                    "engine budget sweep rcv1 {rows}x47236 nnz_budget={budget} t={}",
                    par_pool.threads()
                ),
                || {
                    std::hint::black_box(gdsec_algo::run_states_opts(
                        &prob_b,
                        &cfg_b,
                        sweep_iters,
                        |_k| None,
                        &par_pool,
                        &opts,
                    ));
                },
            );
            context.push((key, Json::num(stats.mean_ns / sweep_iters as f64)));
            reports.push(stats);
        }
    }

    // --- Delay-adaptive quorum vs fixed fractions on a drifting
    //     straggler set (M=8): phase A has one 12-unit straggler among
    //     2-unit workers, phase B has six (only two fast workers left).
    //     A fixed Fraction is wrong in at least one phase — K ≥ 3 waits
    //     12 units per phase-B round, K = 2 runs phase A with six of
    //     eight workers perpetually a round (or the full window) stale —
    //     while Adaptive tracks the fast cluster through the shift and
    //     pays only one transition round. The metric is the summed
    //     virtual round units until the run reaches the tolerance a
    //     cut-free engine run hits at the reference horizon (the
    //     "sync tolerance"); runs are deterministic (seeded problem,
    //     virtual delays), so the ordering is machine-independent.
    //     `engine_adaptive_quorum_units` is presence-gated in CI. ---
    {
        use gdsec::algo::engine::{Engine, EngineOpts};
        use gdsec::algo::gdsec::GdSecRule;
        use gdsec::coordinator::round::Quorum;
        use gdsec::coordinator::scheduler::QuorumSim;
        use gdsec::coordinator::transport::DelayPlan;
        let m_q = 8;
        let ref_iters = if quick { 60 } else { 240 };
        let switch = ref_iters / 2;
        let cap = 4 * ref_iters;
        let window = 3;
        let prob_q = Problem::logistic(synthetic::dna_like(21, 400), m_q, 0.05);
        let cfg_q = GdSecConfig {
            alpha: 1.0 / prob_q.lipschitz(),
            beta: 0.05,
            xi: Xi::Uniform(30.0),
            fstar: Some(0.0),
            eval_every: 1,
            ..Default::default()
        };
        let fstar_q = prob_q.estimate_fstar(2000);
        let plan = DelayPlan::Phased(vec![
            (1, vec![2, 2, 2, 2, 2, 2, 2, 12]),
            (switch, vec![2, 2, 12, 12, 12, 12, 12, 12]),
        ]);
        let opts = EngineOpts { stale_window: window, ..EngineOpts::default() };
        // Sync tolerance: the error a cut-free run reaches at the
        // reference horizon.
        let tol = {
            let rule = GdSecRule::new(cfg_q.clone());
            let mut eng = Engine::new(&prob_q, rule, &par_pool, &opts, fstar_q);
            for _ in 0..ref_iters {
                eng.step(None);
            }
            (prob_q.value(&eng.server.theta) - fstar_q).max(1e-12)
        };
        // Summed virtual units for one quorum policy to reach tol.
        let units_to_tol = |policy: Quorum| -> (u64, usize) {
            let mut sim = QuorumSim::new(m_q, policy, plan.clone(), window);
            let rule = GdSecRule::new(cfg_q.clone());
            let mut eng = Engine::new(&prob_q, rule, &par_pool, &opts, fstar_q);
            let mut total = 0u64;
            for k in 1..=cap {
                let (late, units) = sim.round(k, None);
                eng.step_quorum_aged(None, Some(late));
                total += units;
                if prob_q.value(&eng.server.theta) - fstar_q <= tol {
                    return (total, k);
                }
            }
            (total, cap)
        };
        let adaptive = Quorum::Adaptive { target_quantile: 0.25, min_frac: 0.25 };
        let (adaptive_units, adaptive_rounds) = units_to_tol(adaptive);
        let mut best_fraction_units = u64::MAX;
        let mut best_fraction = 0.0;
        for frac in [0.25, 0.5, 0.75] {
            let (u, r) = units_to_tol(Quorum::Fraction(frac));
            println!(
                "adaptive-quorum bench: Fraction({frac}) reached tol in {r} rounds, {u} units"
            );
            if u < best_fraction_units {
                best_fraction_units = u;
                best_fraction = frac;
            }
        }
        println!(
            "adaptive-quorum bench: Adaptive(q=0.25, min=0.25) reached tol in \
             {adaptive_rounds} rounds, {adaptive_units} units (best fixed: \
             Fraction({best_fraction}) at {best_fraction_units} units)"
        );
        context.push(("engine_adaptive_quorum_units", Json::num(adaptive_units as f64)));
        context.push((
            "engine_best_fraction_quorum_units",
            Json::num(best_fraction_units as f64),
        ));
        context.push((
            "engine_adaptive_vs_best_fraction_units_ratio",
            Json::num(best_fraction_units as f64 / adaptive_units.max(1) as f64),
        ));
    }

    println!("\n== hotpath microbenchmarks ==");
    for r in &reports {
        println!("{}", r.report());
    }
    for (k, v) in &context {
        if let Some(x) = v.as_f64() {
            println!("{k}: {x:.2}");
        }
    }
    let path = out_path();
    match bench::write_json(&path, context, &reports) {
        Ok(()) => println!("bench artifact -> {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}
