//! Hot-path microbenchmarks (custom harness — no criterion offline).
//!
//! Covers every operation on the per-round critical path:
//!   worker: gradient (gemv), sparsify_step (censor+EC), RLE encode
//!   server: decode, aggregate, apply_round
//!   codecs: QSGD quantize/dequantize, protocol frame encode/decode
//!
//! These are the numbers behind EXPERIMENTS.md §Perf.

use gdsec::algo::gdsec::{GdSecConfig, ServerState, WorkerState, Xi};
use gdsec::compress::{self, quantize, SparseUpdate};
use gdsec::coordinator::protocol::{self, Msg};
use gdsec::data::synthetic;
use gdsec::linalg;
use gdsec::objectives::Problem;
use gdsec::util::bench::Bencher;
use gdsec::util::rng::Pcg64;

fn main() {
    let b = Bencher::from_env();
    let mut reports = Vec::new();

    // --- sparsify_step at the paper's dimensions ---
    for &d in &[784usize, 3072, 47236] {
        let mut rng = Pcg64::seeded(d as u64);
        let mut ws = WorkerState::new(d);
        let grad: Vec<f64> = (0..d).map(|_| rng.normal() * 0.1).collect();
        let diff: Vec<f64> = (0..d).map(|_| rng.normal() * 1e-3).collect();
        let cfg = GdSecConfig { xi: Xi::Uniform(100.0), beta: 0.01, ..Default::default() };
        ws.grad_mut().copy_from_slice(&grad);
        reports.push(b.run_units(&format!("sparsify_step d={d}"), d as f64, "elem", || {
            ws.grad_mut().copy_from_slice(&grad);
            let up = ws.sparsify_step(&cfg, 5, &diff);
            std::hint::black_box(up.nnz());
        }));
    }

    // --- gradient (the worker's other half) ---
    let prob = Problem::linear(synthetic::mnist_like(1, 400), 1, 1e-3);
    let l = &prob.locals[0];
    let theta = vec![0.01; prob.d];
    let mut g = vec![0.0; prob.d];
    let elems = (400 * prob.d) as f64;
    reports.push(b.run_units("local grad linreg 400x784", elems, "madd", || {
        l.grad(&theta, &mut g);
        std::hint::black_box(g[0]);
    }));

    // --- RLE codec ---
    let mut rng = Pcg64::seeded(9);
    for &(d, p_zero) in &[(784usize, 0.5), (47236, 0.95)] {
        let v: Vec<f64> =
            (0..d).map(|_| if rng.bernoulli(p_zero) { 0.0 } else { rng.normal() }).collect();
        let up = SparseUpdate::from_dense(&v);
        let mut buf = Vec::with_capacity(8 * d);
        reports.push(b.run_units(
            &format!("rle encode d={d} nnz={}", up.nnz()),
            up.nnz() as f64,
            "entry",
            || {
                buf.clear();
                compress::encode_sparse(&up, &mut buf);
                std::hint::black_box(buf.len());
            },
        ));
        compress::encode_sparse(&up, &mut buf);
        reports.push(b.run_units(
            &format!("rle decode d={d} nnz={}", up.nnz()),
            up.nnz() as f64,
            "entry",
            || {
                let (u, _) = compress::decode_sparse(&buf, d as u32).unwrap();
                std::hint::black_box(u.nnz());
            },
        ));
    }

    // --- QSGD quantizer ---
    let v: Vec<f64> = (0..3072).map(|_| rng.normal()).collect();
    reports.push(b.run_units("qsgd quantize d=3072", 3072.0, "elem", || {
        let q = quantize::quantize(&v, 255, &mut rng);
        std::hint::black_box(q.idx.len());
    }));

    // --- server aggregate + apply ---
    let d = 3072;
    let mut server = ServerState::new(d);
    let updates: Vec<SparseUpdate> = (0..100)
        .map(|w| {
            let vv: Vec<f64> = (0..d)
                .map(|i| if (i + w) % 10 == 0 { 0.5 } else { 0.0 })
                .collect();
            SparseUpdate::from_dense(&vv)
        })
        .collect();
    let cfg = GdSecConfig { alpha: 1e-3, beta: 0.01, ..Default::default() };
    reports.push(b.run_units("server apply_round M=100 d=3072", 100.0, "update", || {
        server.apply_round(&cfg, &updates);
        std::hint::black_box(server.theta[0]);
    }));

    // --- protocol framing ---
    let v: Vec<f64> = (0..784).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let up = SparseUpdate::from_dense(&v);
    let msg = Msg::Update { round: 5, worker: 2, update: up, local_f: 0.25 };
    reports.push(b.run("protocol encode+decode update d=784", || {
        let buf = protocol::encode(&msg, 784);
        let m = protocol::decode(&buf, 784).unwrap();
        std::hint::black_box(matches!(m, Msg::Update { .. }));
    }));

    // --- dot product roofline reference ---
    let x: Vec<f64> = (0..4096).map(|i| i as f64).collect();
    reports.push(b.run_units("dot 4096", 4096.0, "madd", || {
        std::hint::black_box(linalg::dot(&x, &x));
    }));

    println!("\n== hotpath microbenchmarks ==");
    for r in &reports {
        println!("{}", r.report());
    }
}
