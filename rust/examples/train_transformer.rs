//! END-TO-END DRIVER — the full three-layer stack on a real training
//! workload:
//!
//!   L1  Pallas `gdsec_sparsify` kernel (compiled into the artifacts)
//!   L2  jax transformer LM fwd/bwd, AOT-lowered to `artifacts/*.hlo.txt`
//!   L3  this Rust coordinator: threaded workers, framed protocol,
//!       RLE-coded sparsified gradient differences on the uplink
//!
//! A ~330k-parameter decoder-only transformer is trained with distributed
//! full-batch GD-SEC across M worker threads, each worker owning a shard
//! of a synthetic Markov token corpus and executing the compiled jax
//! loss+grad via PJRT. Python never runs here — build artifacts first:
//!
//!   make artifacts && cargo run --release --example train_transformer
//!       [-- --workers 4 --iters 200 --xi 25 --beta 0.05 --alpha 0.3]
//!
//! Outputs: loss curve + uplink accounting -> results/e2e_loss.csv, and a
//! summary (recorded in EXPERIMENTS.md).

use gdsec::compress;
use gdsec::coordinator::worker::GradProvider;
use gdsec::runtime::engine::TfmEngine;
use gdsec::runtime::Manifest;
use gdsec::util::cli::Args;
use gdsec::util::csv::CsvWriter;
use gdsec::util::tablefmt::{bits, pct};
use gdsec::util::Timer;

/// PJRT-backed provider: one compiled transformer engine + a fixed local
/// token shard per worker.
struct TfmProvider {
    eng: TfmEngine,
    tokens: Vec<i32>,
    scratch: Vec<f32>,
}

impl TfmProvider {
    fn new(manifest: Manifest, tokens: Vec<i32>) -> Self {
        let eng = TfmEngine::new(manifest).expect("tfm engine");
        let n = eng.n_params;
        TfmProvider { eng, tokens, scratch: vec![0.0; n] }
    }
}

impl GradProvider for TfmProvider {
    fn dim(&self) -> usize {
        self.eng.n_params
    }

    fn loss_grad(&mut self, theta: &[f64], out: &mut [f64]) -> f64 {
        for (s, &t) in self.scratch.iter_mut().zip(theta) {
            *s = t as f32;
        }
        let (loss, grad) = self.eng.loss_grad(&self.scratch, &self.tokens).expect("loss_grad");
        for (o, g) in out.iter_mut().zip(&grad) {
            *o = *g as f64;
        }
        loss
    }
}

fn main() {
    let args = Args::from_env(false).unwrap();
    let m = args.get_usize("workers", 4).unwrap();
    let iters = args.get_usize("iters", 200).unwrap();
    let alpha = args.get_f64("alpha", 0.3).unwrap();
    let beta = args.get_f64("beta", 0.05).unwrap();
    let xi_over_m = args.get_f64("xi", 25.0).unwrap();
    let seed = args.get_u64("seed", 42).unwrap();

    let manifest = Manifest::load(Manifest::default_dir())
        .expect("artifacts missing — run `make artifacts` first");

    // Server-side engine: initialization + config introspection.
    let mut server_eng = TfmEngine::new(manifest.clone()).expect("server engine");
    let d = server_eng.n_params;
    let (batch, seq, vocab) = (server_eng.batch, server_eng.seq, server_eng.vocab);
    println!("== e2e transformer: {d} params, vocab {vocab}, seq {seq}, batch {batch}/worker, M={m} ==");
    let theta0_f32 = server_eng.init_params(seed as i32).expect("init");
    let theta0: Vec<f64> = theta0_f32.iter().map(|&v| v as f64).collect();

    // Shard the corpus: each worker holds `batch` sequences.
    let corpus = gdsec::data::synthetic::token_corpus(seed, m * batch, seq, vocab);
    let shards: Vec<Vec<i32>> = (0..m)
        .map(|w| {
            corpus[w * batch..(w + 1) * batch]
                .iter()
                .flat_map(|s| s.iter().map(|&t| t as i32))
                .collect()
        })
        .collect();

    // --- GD-SEC over the full stack (serial round loop driving PJRT
    //     providers; the threaded-coordinator variant of this same seam is
    //     exercised by integration tests — here we keep all M PJRT
    //     instances in one thread since the box has a single core). ---
    let mut providers: Vec<TfmProvider> =
        shards.iter().map(|s| TfmProvider::new(manifest.clone(), s.clone())).collect();

    let xi = xi_over_m * m as f64;
    let mut theta = theta0.clone();
    let mut theta_prev = theta0.clone();
    let mut h = vec![0.0f64; d];
    let mut workers: Vec<gdsec::algo::gdsec::WorkerState> =
        (0..m).map(|_| gdsec::algo::gdsec::WorkerState::new(d)).collect();
    let cfg = gdsec::algo::gdsec::GdSecConfig {
        alpha,
        beta,
        xi: gdsec::algo::gdsec::Xi::Uniform(xi),
        ..Default::default()
    };

    std::fs::create_dir_all("results").ok();
    let mut csv = CsvWriter::create(
        "results/e2e_loss.csv",
        &["iter", "loss", "payload_bits", "dense_bits", "tx", "entries", "secs"],
    )
    .unwrap();

    let timer = Timer::start();
    let (mut payload_bits, mut tx_count, mut entries) = (0u64, 0u64, 0u64);
    // Adaptive dense/sparse fallback accounting (extension beyond the
    // paper: caps the cost of weakly-censored rounds at 8 + 32·d bits).
    let mut adaptive_bits_total = 0u64;
    let mut theta_diff = vec![0.0f64; d];
    let mut first_loss = f64::NAN;
    let mut last_loss = f64::NAN;
    for k in 1..=iters {
        for i in 0..d {
            theta_diff[i] = theta[i] - theta_prev[i];
        }
        let mut agg = vec![0.0f64; d];
        let mut round_loss = 0.0;
        for (w, prov) in providers.iter_mut().enumerate() {
            let loss = prov.loss_grad(&theta, workers[w].grad_mut());
            round_loss += loss;
            let up = workers[w].sparsify_step(&cfg, m, &theta_diff);
            if up.nnz() > 0 {
                payload_bits += compress::sparse_bits(&up) as u64;
                adaptive_bits_total += compress::adaptive_bits(&up) as u64;
                tx_count += 1;
                entries += up.nnz() as u64;
                up.add_into(&mut agg);
            }
        }
        let mean_loss = round_loss / m as f64;
        if k == 1 {
            first_loss = mean_loss;
        }
        last_loss = mean_loss;
        theta_prev.copy_from_slice(&theta);
        for i in 0..d {
            theta[i] -= alpha * (h[i] + agg[i]);
            h[i] += beta * agg[i];
        }
        let dense_bits = (k * m) as u64 * compress::dense_bits(d) as u64;
        csv.row_f64(&[
            k as f64,
            mean_loss,
            payload_bits as f64,
            dense_bits as f64,
            tx_count as f64,
            entries as f64,
            timer.elapsed_secs(),
        ])
        .unwrap();
        if k % 10 == 0 || k == 1 {
            println!(
                "  iter {k:>4}  loss {mean_loss:.4}  uplink {:>10}  (dense would be {:>10})  [{:.1}s]",
                bits(payload_bits as f64),
                bits(dense_bits as f64),
                timer.elapsed_secs()
            );
        }
    }
    csv.flush().unwrap();

    let dense_total = (iters * m) as u64 * compress::dense_bits(d) as u64;
    println!("\n== summary ==");
    println!("  loss: {first_loss:.4} -> {last_loss:.4} (uniform baseline ln(V) = {:.4})", (vocab as f64).ln());
    println!(
        "  uplink payload {} vs dense GD {} -> {} saved",
        bits(payload_bits as f64),
        bits(dense_total as f64),
        pct(1.0 - payload_bits as f64 / dense_total as f64)
    );
    println!(
        "  with adaptive dense-fallback framing: {} -> {} saved",
        bits(adaptive_bits_total as f64),
        pct(1.0 - adaptive_bits_total as f64 / dense_total as f64)
    );
    println!("  transmissions {tx_count} / {}", iters * m);
    println!("  wall time {:.1}s  -> results/e2e_loss.csv", timer.elapsed_secs());
}
