//! Ablation grid over GD-SEC's three ingredients (paper §II-A):
//! adaptive sparsification x error correction x state variables,
//! on the lasso/DNA-like workload of Fig 3.
//!
//! Run: `cargo run --release --example compressor_ablation`

use gdsec::algo::gd;
use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::tablefmt::{bits, sci, Table};

fn main() {
    let n = 2000;
    let data = synthetic::dna_like(3, n);
    let prob = Problem::lasso(data, 5, 1.0 / n as f64);
    let alpha = 1.0 / prob.lipschitz();
    let iters = 1500;
    let m = prob.m() as f64;
    let fstar = prob.estimate_fstar(6000);

    let mut table = Table::new(&["variant", "ξ/M", "final err", "uplink", "tx"]);
    let gd_trace =
        gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: Some(fstar) }, iters);
    table.row(vec![
        "GD (dense)".into(),
        "-".into(),
        sci(gd_trace.final_error()),
        bits(gd_trace.total_bits() as f64),
        gd_trace.total_transmissions().to_string(),
    ]);

    // (error-correction, state-variable, ξ/M) — thresholds tuned for the
    // dna-like substitute (fig3 runner): EC tolerates ~25x larger ξ.
    let grid = [
        ("GD-SEC (EC+SV)", true, true, 500.0),
        ("EC only (no SV)", true, false, 20.0),
        ("SV only (no EC) = GD-SOEC", false, true, 20.0),
        ("neither (hard censor)", false, false, 20.0),
        ("GD-SOEC at SEC's ξ", false, true, 500.0),
    ];
    for (label, ec, sv, xi_over_m) in grid {
        let cfg = GdSecConfig {
            alpha,
            beta: if sv { 0.01 } else { 0.0 },
            xi: Xi::Uniform(xi_over_m * m),
            error_correction: ec,
            state_variable: sv,
            eval_every: 1,
            fstar: Some(fstar),
        };
        let t = gdsec_algo::run(&prob, &cfg, iters);
        table.row(vec![
            label.into(),
            format!("{xi_over_m}"),
            sci(t.final_error()),
            bits(t.total_bits() as f64),
            t.total_transmissions().to_string(),
        ]);
    }
    println!("== GD-SEC ingredient ablation (lasso / dna-like, {iters} iters) ==");
    println!("{}", table.render());
    println!("Takeaways (paper §IV-C/D): error correction lets ξ grow ~25x;");
    println!("state variables let the server coast through censored rounds.");
}
