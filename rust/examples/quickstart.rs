//! Quickstart: GD vs GD-SEC on the paper's synthetic logistic-regression
//! workload (Fig 2 setup) in ~20 lines of library use.
//!
//! Run: `cargo run --release --example quickstart`

use gdsec::algo::gd;
use gdsec::algo::gdsec as gdsec_algo;
use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::tablefmt::{bits, pct};

fn main() {
    // 5 workers, 50 samples each, d = 300 (the paper's own recipe).
    let data = synthetic::paper_logreg(42, 5, 50, 300);
    let n = data.n();
    let prob = Problem::logistic(data, 5, 1.0 / n as f64);
    let alpha = 1.0 / prob.lipschitz();
    let iters = 1000;

    let t_gd = gd::run(&prob, &gd::GdConfig { alpha, eval_every: 1, fstar: None }, iters);
    let cfg = GdSecConfig {
        alpha,
        beta: 0.01,
        xi: Xi::Uniform(80.0 * prob.m() as f64), // paper: ξ/M = 80
        ..Default::default()
    };
    let t_sec = gdsec_algo::run(&prob, &cfg, iters);

    let eps = t_gd.final_error().max(t_sec.final_error()) * 2.0;
    println!("target objective error: {eps:.3e}");
    for t in [&t_gd, &t_sec] {
        println!(
            "  {:<8} iters {:>5}  uplink {:>10}  transmissions {:>6}",
            t.algo,
            t.iters_to_reach(eps).map_or_else(|| "-".to_string(), |v| v.to_string()),
            bits(t.bits_to_reach(eps).unwrap_or(0) as f64),
            t.total_transmissions(),
        );
    }
    println!(
        "GD-SEC saves {} of the uplink bits at equal accuracy.",
        pct(t_sec.savings_vs(&t_gd, eps))
    );
}
