//! Bandwidth-limited federated scenario (Fig 8): 100 workers on
//! CIFAR-like data, round-robin scheduling of half the fleet per round,
//! run through the REAL threaded coordinator (framed protocol, byte
//! counters, failure tolerance) rather than the serial reference.
//!
//! Run: `cargo run --release --example federated_rr [-- --workers 100 --iters 300]`

use gdsec::algo::gdsec::{GdSecConfig, Xi};
use gdsec::coordinator::scheduler::Scheduler;
use gdsec::data::synthetic;
use gdsec::objectives::Problem;
use gdsec::util::cli::Args;
use gdsec::util::tablefmt::bits;

fn main() {
    let args = Args::from_env(false).unwrap();
    let m = args.get_usize("workers", 100).unwrap();
    let iters = args.get_usize("iters", 300).unwrap();
    let n = args.get_usize("samples", 2000).unwrap();

    let data = synthetic::cifar_like(7, n);
    let prob = Problem::linear(data, m, 1.0 / n as f64);
    let alpha = 1.0 / prob.lipschitz();

    println!("== federated round-robin: M={m}, d={}, {iters} rounds ==", prob.d);
    for (label, sched, xi_over_m) in [
        ("all workers", Scheduler::All, 4000.0),
        ("RR half", Scheduler::RoundRobin { fraction: 0.5 }, 400.0),
    ] {
        let cfg = GdSecConfig {
            alpha,
            beta: 0.01,
            xi: Xi::Uniform(xi_over_m * m as f64),
            ..Default::default()
        };
        let out = gdsec::coordinator::run_native(&prob, cfg, iters, sched);
        let payload: u64 = out.rounds.iter().map(|r| r.payload_bits).sum();
        let overhead: u64 = out.rounds.iter().map(|r| r.overhead_bits).sum();
        println!(
            "  {label:<12} ξ/M={xi_over_m:<5} f-f* {:.3e} | payload {:>10} | overhead {:>9} | mean round {:>7.0}µs",
            out.trace.final_error(),
            bits(payload as f64),
            bits(overhead as f64),
            out.rounds.iter().map(|r| r.wall_us as f64).sum::<f64>() / out.rounds.len() as f64,
        );
    }
    println!("(GD-SEC with half participation keeps nearly full-fleet accuracy — Fig 8.)");
}
