"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes and value regimes; assert_allclose against ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gdsec_sparsify import (
    BLOCK,
    bytes_moved_per_element,
    gdsec_sparsify,
    vmem_bytes_per_block,
)
from compile.kernels.linreg_grad import linreg_grad, vmem_bytes_per_block as lr_vmem


def _rand(key, shape, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(jnp.float32)


def run_both(d, seed, beta=0.01, m_inv=0.2, xi_scale=1.0, block=BLOCK):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    grad = _rand(keys[0], (d,))
    h = _rand(keys[1], (d,), 0.5)
    e = _rand(keys[2], (d,), 0.1)
    tdiff = _rand(keys[3], (d,), 0.01)
    xi = jnp.abs(_rand(keys[4], (d,), xi_scale)) * 100.0
    scalars = jnp.array([beta, m_inv], jnp.float32)
    got = gdsec_sparsify(grad, h, e, tdiff, xi, scalars, block=block)
    want = ref.gdsec_sparsify_ref(grad, h, e, tdiff, xi, beta, m_inv)
    return got, want


class TestGdsecSparsify:
    @pytest.mark.parametrize("d", [1, 7, 128, 1024, 1025, 4096, 5000])
    def test_matches_ref_across_dims(self, d):
        got, want = run_both(d, seed=d)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-7)

    @given(
        d=st.integers(min_value=1, max_value=3000),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        beta=st.floats(min_value=0.001, max_value=1.0),
        m_inv=st.floats(min_value=0.01, max_value=1.0),
    )
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_sweep(self, d, seed, beta, m_inv):
        got, want = run_both(d, seed=seed, beta=beta, m_inv=m_inv)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-6)

    def test_ec_identity(self):
        # wire + e_new == delta exactly (f32 arithmetic both sides)
        (wire, h_new, e_new), _ = run_both(513, seed=3)
        keys = jax.random.split(jax.random.PRNGKey(3), 5)
        grad = _rand(keys[0], (513,))
        h = _rand(keys[1], (513,), 0.5)
        e = _rand(keys[2], (513,), 0.1)
        delta = grad - h + e
        np.testing.assert_array_equal(np.asarray(wire + e_new), np.asarray(delta))
        del h_new

    def test_zero_xi_transmits_all_nonzero(self):
        d = 256
        grad = jnp.ones((d,), jnp.float32)
        zeros = jnp.zeros((d,), jnp.float32)
        scal = jnp.array([0.5, 0.2], jnp.float32)
        wire, h_new, e_new = gdsec_sparsify(grad, zeros, zeros, zeros, zeros, scal)
        np.testing.assert_array_equal(np.asarray(wire), np.ones(d, np.float32))
        np.testing.assert_allclose(np.asarray(h_new), 0.5 * np.ones(d), rtol=1e-7)
        np.testing.assert_array_equal(np.asarray(e_new), np.zeros(d, np.float32))

    def test_huge_xi_suppresses_everything(self):
        d = 300
        key = jax.random.PRNGKey(0)
        grad = _rand(key, (d,), 0.01)
        zeros = jnp.zeros((d,), jnp.float32)
        tdiff = jnp.ones((d,), jnp.float32)
        xi = jnp.full((d,), 1e9, jnp.float32)
        scal = jnp.array([0.5, 1.0], jnp.float32)
        wire, h_new, e_new = gdsec_sparsify(grad, zeros, zeros, tdiff, xi, scal)
        assert np.all(np.asarray(wire) == 0.0)
        assert np.all(np.asarray(h_new) == 0.0)
        np.testing.assert_array_equal(np.asarray(e_new), np.asarray(grad))

    def test_beta_one_h_tracks_wire(self):
        (wire, h_new, _), _ = run_both(128, seed=9, beta=1.0)
        # h started random; h_new - h == wire (beta=1)
        keys = jax.random.split(jax.random.PRNGKey(9), 5)
        h = _rand(keys[1], (128,), 0.5)
        np.testing.assert_allclose(
            np.asarray(h_new - h), np.asarray(wire), rtol=1e-6, atol=1e-7
        )

    @pytest.mark.parametrize("block", [128, 256, 1024])
    def test_block_size_invariance(self, block):
        got_a, _ = run_both(2048, seed=5, block=block)
        got_b, _ = run_both(2048, seed=5, block=BLOCK)
        for a, b in zip(got_a, got_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_structural_metrics(self):
        # VMEM: 9 tiles of BLOCK f32 (BLOCK=32768 after the §Perf sweep:
        # 1.2 MiB/step, ~6x double-buffer headroom on a 16 MiB core);
        # 32 B/elem HBM traffic.
        assert vmem_bytes_per_block() == 9 * BLOCK * 4
        assert vmem_bytes_per_block() < 4 * 1024 * 1024
        assert bytes_moved_per_element() == 32


class TestLinregGrad:
    @pytest.mark.parametrize("n,d", [(1, 1), (5, 3), (128, 64), (130, 50), (300, 784)])
    def test_matches_ref(self, n, d):
        keys = jax.random.split(jax.random.PRNGKey(n * 1000 + d), 3)
        x = _rand(keys[0], (n, d))
        y = _rand(keys[1], (n,))
        theta = _rand(keys[2], (d,))
        n_total = float(4 * n)
        got = linreg_grad(x, y, theta, jnp.array([1.0 / n_total], jnp.float32))
        want = ref.linreg_grad_ref(x, y, theta, n_total)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-6)

    @given(
        n=st.integers(min_value=1, max_value=300),
        d=st.integers(min_value=1, max_value=128),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    @settings(max_examples=20, deadline=None)
    def test_hypothesis_sweep(self, n, d, seed):
        keys = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = _rand(keys[0], (n, d))
        y = _rand(keys[1], (n,))
        theta = _rand(keys[2], (d,), 0.3)
        got = linreg_grad(x, y, theta, jnp.array([0.01], jnp.float32))
        want = ref.linreg_grad_ref(x, y, theta, 100.0)
        np.testing.assert_allclose(got, want, rtol=3e-4, atol=1e-6)

    def test_row_block_invariance(self):
        keys = jax.random.split(jax.random.PRNGKey(1), 3)
        x = _rand(keys[0], (257, 33))
        y = _rand(keys[1], (257,))
        theta = _rand(keys[2], (33,))
        s = jnp.array([0.001], jnp.float32)
        a = linreg_grad(x, y, theta, s, row_block=64)
        b = linreg_grad(x, y, theta, s, row_block=128)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-8)

    def test_vmem_estimate(self):
        assert lr_vmem(784) == 4 * (128 * 784 + 2 * 784 + 128 + 1)
