"""L2 correctness: worker-step functions and the transformer LM."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _shard(seed, n=20, d=50):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = (jax.random.normal(keys[0], (n, d)) * 0.5).astype(jnp.float32)
    y = jnp.sign(jax.random.normal(keys[1], (n,))).astype(jnp.float32)
    theta = (jax.random.normal(keys[2], (d,)) * 0.1).astype(jnp.float32)
    return x, y, theta


class TestWorkerStep:
    @pytest.mark.parametrize("kind", ["linreg", "logreg", "nlls"])
    def test_loss_matches_autodiff_grad(self, kind):
        # With xi=0 (transmit everything), wire == grad - h + e; pick
        # h=e=0 so wire == local gradient, pinned against jax.grad.
        x, y, theta = _shard(1)
        d = theta.shape[0]
        zeros = jnp.zeros((d,), jnp.float32)
        scalars = jnp.array([0.01, 0.2, 1.0 / 80.0, 0.05], jnp.float32)
        step = model.make_worker_step(kind)
        wire, h_new, e_new, loss = step(
            x, y, theta, theta, zeros, zeros, zeros, scalars
        )

        def loss_fn(t):
            return model._local_loss(kind, x, y, t, 1.0 / 80.0, 0.05 * 0.2)

        want_grad = jax.grad(loss_fn)(theta)
        np.testing.assert_allclose(wire, want_grad, rtol=3e-3, atol=2e-5)
        np.testing.assert_allclose(loss[0], loss_fn(theta), rtol=1e-5)
        np.testing.assert_allclose(h_new, 0.01 * wire, rtol=1e-6, atol=1e-8)
        # EC identity
        np.testing.assert_allclose(
            wire + e_new, wire, atol=1e-6
        )  # e_new ~ f32 rounding only

    @pytest.mark.parametrize("kind", ["linreg", "logreg", "nlls"])
    def test_censoring_consistent_with_ref(self, kind):
        x, y, theta = _shard(2)
        d = theta.shape[0]
        keys = jax.random.split(jax.random.PRNGKey(7), 4)
        h = (jax.random.normal(keys[0], (d,)) * 0.05).astype(jnp.float32)
        e = (jax.random.normal(keys[1], (d,)) * 0.01).astype(jnp.float32)
        theta_prev = theta - (jax.random.normal(keys[2], (d,)) * 0.01).astype(jnp.float32)
        xi = jnp.abs(jax.random.normal(keys[3], (d,))).astype(jnp.float32) * 50.0
        scalars = jnp.array([0.05, 0.25, 0.01, 0.1], jnp.float32)
        step = model.make_worker_step(kind)
        wire, h_new, e_new, _ = step(x, y, theta, theta_prev, h, e, xi, scalars)
        # Rebuild via oracle using the same gradient (from the step with
        # xi=0, h=e=0 it equals wire; here recompute directly):
        grad = model._local_grad(kind, x, y, theta, 0.01, 0.1 * 0.25)
        w_want, h_want, e_want = ref.gdsec_sparsify_ref(
            grad, h, e, theta - theta_prev, xi, 0.05, 0.25
        )
        np.testing.assert_allclose(wire, w_want, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(h_new, h_want, rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(e_new, e_want, rtol=1e-5, atol=1e-7)


class TestTransformer:
    def small_cfg(self):
        return model.TfmConfig(vocab=17, seq=8, d_model=16, n_layers=2, n_heads=2, d_ff=24)

    def test_param_count_matches_flat_vector(self):
        cfg = self.small_cfg()
        flat = model.init_params(cfg, jax.random.PRNGKey(0))
        assert flat.shape == (cfg.n_params(),)
        p = model.unflatten(cfg, flat)
        assert p["tok_embed"].shape == (17, 16)
        assert p["l1.mlp.w1"].shape == (16, 24)

    def test_forward_shapes_and_loss_finite(self):
        cfg = self.small_cfg()
        flat = model.init_params(cfg, jax.random.PRNGKey(1))
        tokens = jax.random.randint(jax.random.PRNGKey(2), (3, cfg.seq), 0, cfg.vocab)
        logits = model.forward(cfg, flat, tokens)
        assert logits.shape == (3, cfg.seq, cfg.vocab)
        loss = model.lm_loss(cfg, flat, tokens)
        assert np.isfinite(float(loss))
        # At init the loss should be near ln(vocab) (head is not
        # zero-initialized, so allow some slack).
        assert abs(float(loss) - np.log(cfg.vocab)) < 1.0

    def test_causality(self):
        # Changing a future token must not affect earlier logits.
        cfg = self.small_cfg()
        flat = model.init_params(cfg, jax.random.PRNGKey(3))
        tokens = jax.random.randint(jax.random.PRNGKey(4), (1, cfg.seq), 0, cfg.vocab)
        logits_a = model.forward(cfg, flat, tokens)
        tokens_b = tokens.at[0, -1].set((tokens[0, -1] + 1) % cfg.vocab)
        logits_b = model.forward(cfg, flat, tokens_b)
        np.testing.assert_allclose(
            logits_a[0, : cfg.seq - 1], logits_b[0, : cfg.seq - 1], atol=1e-5
        )

    def test_grad_descends(self):
        cfg = self.small_cfg()
        flat = model.init_params(cfg, jax.random.PRNGKey(5))
        tokens = jax.random.randint(jax.random.PRNGKey(6), (4, cfg.seq), 0, cfg.vocab)
        loss_grad = model.make_tfm_loss_grad(cfg)
        l0, g = loss_grad(flat, tokens)
        assert g.shape == flat.shape
        flat2 = flat - 0.5 * g
        l1, _ = loss_grad(flat2, tokens)
        assert float(l1[0]) < float(l0[0])

    def test_grad_matches_fd_spotcheck(self):
        cfg = self.small_cfg()
        flat = model.init_params(cfg, jax.random.PRNGKey(7))
        tokens = jax.random.randint(jax.random.PRNGKey(8), (2, cfg.seq), 0, cfg.vocab)
        loss_grad = model.make_tfm_loss_grad(cfg)
        _, g = loss_grad(flat, tokens)
        f = lambda q: float(model.lm_loss(cfg, q, tokens))
        eps = 1e-3
        for idx in [0, 57, cfg.n_params() - 1]:
            fp = f(flat.at[idx].add(eps))
            fm = f(flat.at[idx].add(-eps))
            fd = (fp - fm) / (2 * eps)
            assert abs(fd - float(g[idx])) < 5e-2 * max(abs(fd), 1.0), (
                f"idx {idx}: fd {fd} vs ad {float(g[idx])}"
            )
