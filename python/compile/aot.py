"""AOT lowering: JAX/Pallas (L2+L1) -> HLO text artifacts for the Rust
PJRT runtime (L3).

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published `xla` 0.1.6 crate links) rejects; the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.

Usage:
    cd python && python -m compile.aot --out ../artifacts \
        [--tfm-vocab 256 --tfm-seq 32 --tfm-dmodel 128 ...]

Writes one `<name>.hlo.txt` per artifact plus `manifest.json` describing
input/output shapes — the Rust runtime loads executables by manifest name.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class ArtifactBuilder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"format": "hlo-text", "artifacts": []}

    def add(self, name, fn, in_specs, meta=None):
        """Lower fn at the given input specs and write the artifact."""
        lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *[s for _, s in in_specs])
        if not isinstance(out_shapes, (tuple, list)):
            out_shapes = (out_shapes,)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [_shape_entry(n, s) for n, s in in_specs],
            "outputs": [_shape_entry(f"out{i}", s) for i, s in enumerate(out_shapes)],
        }
        if meta:
            entry["meta"] = meta
        self.manifest["artifacts"].append(entry)
        print(f"  wrote {fname}  ({len(text)} chars, "
              f"{len(entry['inputs'])} in / {len(entry['outputs'])} out)")

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=2, sort_keys=True)
        print(f"  wrote manifest.json ({len(self.manifest['artifacts'])} artifacts)")


def worker_step_specs(n, d):
    """Input spec list for a worker-step artifact over an (n, d) shard."""
    return [
        ("x", spec((n, d))),
        ("y", spec((n,))),
        ("theta", spec((d,))),
        ("theta_prev", spec((d,))),
        ("h", spec((d,))),
        ("e", spec((d,))),
        ("xi", spec((d,))),
        ("scalars", spec((4,))),  # [beta, 1/M, 1/N, lambda]
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--tfm-vocab", type=int, default=256)
    ap.add_argument("--tfm-seq", type=int, default=32)
    ap.add_argument("--tfm-dmodel", type=int, default=128)
    ap.add_argument("--tfm-layers", type=int, default=2)
    ap.add_argument("--tfm-heads", type=int, default=4)
    ap.add_argument("--tfm-dff", type=int, default=256)
    ap.add_argument("--tfm-batch", type=int, default=4)
    # Worker-step shard shapes to pre-compile: "n x d" pairs.
    ap.add_argument(
        "--shards",
        default="30x180:logreg,30x180:linreg,20x180:nlls",
        help="comma list of NxD:kind worker-step artifacts",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    print(f"AOT-lowering artifacts to {args.out}")

    b = ArtifactBuilder(args.out)

    # --- Worker-step artifacts (objective grad + Pallas sparsify fused) ---
    for part in args.shards.split(","):
        shape, kind = part.strip().split(":")
        n, d = (int(v) for v in shape.split("x"))
        fn = model.make_worker_step(kind)
        b.add(
            f"worker_step_{kind}_{n}x{d}",
            fn,
            worker_step_specs(n, d),
            meta={"kind": kind, "n": n, "d": d},
        )

    # --- Standalone sparsify kernel (used by the transformer e2e path) ---
    cfg = model.TfmConfig(
        vocab=args.tfm_vocab,
        seq=args.tfm_seq,
        d_model=args.tfm_dmodel,
        n_layers=args.tfm_layers,
        n_heads=args.tfm_heads,
        d_ff=args.tfm_dff,
    )
    n_params = int(cfg.n_params())

    from .kernels.gdsec_sparsify import gdsec_sparsify

    def sparsify_fn(grad, h, e, theta_diff, xi, scalars):
        return gdsec_sparsify(grad, h, e, theta_diff, xi, scalars)

    b.add(
        f"gdsec_sparsify_{n_params}",
        sparsify_fn,
        [
            ("grad", spec((n_params,))),
            ("h", spec((n_params,))),
            ("e", spec((n_params,))),
            ("theta_diff", spec((n_params,))),
            ("xi", spec((n_params,))),
            ("scalars", spec((2,))),  # [beta, 1/M]
        ],
        meta={"d": n_params},
    )

    # --- Transformer loss+grad ---
    loss_grad = model.make_tfm_loss_grad(cfg)
    b.add(
        "tfm_loss_grad",
        loss_grad,
        [
            ("params", spec((n_params,))),
            ("tokens", spec((args.tfm_batch, cfg.seq), jnp.int32)),
        ],
        meta={
            "n_params": n_params,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "batch": args.tfm_batch,
        },
    )

    # --- Transformer init params (lowered as a computation so Rust can
    #     materialize the same initialization without Python) ---
    def tfm_init(seed_arr):
        key = jax.random.PRNGKey(seed_arr[0])
        return model.init_params(cfg, key)

    b.add(
        "tfm_init",
        tfm_init,
        [("seed", spec((1,), jnp.int32))],
        meta={"n_params": n_params},
    )

    b.finish()
    print("AOT done.")


if __name__ == "__main__":
    main()
