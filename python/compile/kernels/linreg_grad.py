"""L1 Pallas kernel: tiled shard gradient for (regularized) linear
regression — the compute hot spot of the paper's Fig 1/4/8 workloads:

    g = (1/N) * X^T (X @ theta - y)        X: f32[n_m, d]

TPU adaptation (DESIGN.md §Hardware-Adaptation): two MXU matmuls per row
tile. The grid walks row blocks of X; each step keeps one (bm, d) tile of
X in VMEM, computes the block residual r = X_blk @ theta - y_blk and
accumulates X_blk^T r into the d-vector output, which stays resident
across the sequential TPU grid (revisiting output blocks is the standard
Pallas accumulation idiom). For the shard sizes in this repo (d <= 3072)
theta and the accumulator fit comfortably in VMEM next to the X tile
(structural footprint reported by `vmem_bytes_per_block`).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _kernel(x_ref, y_ref, theta_ref, scal_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    n_inv = scal_ref[0]
    x = x_ref[...]
    r = x @ theta_ref[...] - y_ref[...]
    out_ref[...] += n_inv * (r @ x)


@functools.partial(jax.jit, static_argnames=("row_block",))
def linreg_grad(x, y, theta, scalars, *, row_block=ROW_BLOCK):
    """Data-term gradient (1/N)·X^T(Xθ−y) with row-tiled accumulation.

    Args:
      x: f32[n, d] shard features.
      y: f32[n] shard labels.
      theta: f32[d].
      scalars: f32[1] = [1/N] (N = global sample count, per Eq. 19).
    Returns:
      f32[d] data-term gradient (regularizer added by the caller at L2).
    """
    n, d = x.shape
    bm = min(row_block, max(n, 1))
    np_ = _round_up(max(n, 1), bm)
    pad = np_ - n
    if pad:
        # Zero rows contribute zero residual -> inert padding.
        x = jnp.pad(x, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad))
    grid = np_ // bm
    return pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, y, theta, scalars)


def _round_up(v, to):
    return ((v + to - 1) // to) * to


def vmem_bytes_per_block(d, row_block=ROW_BLOCK, dtype_bytes=4):
    """Structural VMEM footprint per grid step: X tile + theta + y + out."""
    return dtype_bytes * (row_block * d + 2 * d + row_block + 1)
