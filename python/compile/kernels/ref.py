"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package is
pinned against its oracle by pytest + hypothesis sweeps, and the Rust
native implementation mirrors the same math (pinned on the Rust side).
"""

import jax.numpy as jnp


def gdsec_sparsify_ref(grad, h, e, theta_diff, xi, beta, m_inv):
    """GD-SEC worker step (Algorithm 1, lines 4-15), vectorized.

    delta   = grad - h + e
    tau_i   = xi_i * m_inv * |theta_diff_i|
    keep_i  = |delta_i| > tau_i
    wire    = delta * keep                  (the transmitted sparse vector)
    h_new   = h + beta * wire
    e_new   = delta - wire

    Returns (wire, h_new, e_new).
    """
    delta = grad - h + e
    tau = xi * m_inv * jnp.abs(theta_diff)
    keep = jnp.abs(delta) > tau
    wire = jnp.where(keep, delta, 0.0).astype(grad.dtype)
    h_new = h + beta * wire
    e_new = delta - wire
    return wire, h_new, e_new


def linreg_grad_ref(x, y, theta, n_total):
    """Data-term gradient of regularized linear regression (Eq. 19):
    (1/N) * X^T (X theta - y). Regularizer is added by the caller."""
    r = x @ theta - y
    return (x.T @ r) / n_total


def logreg_grad_ref(x, y, theta, n_total):
    """Data-term gradient of logistic regression (Eq. 20)."""
    z = x @ theta
    # s = sigmoid(-y*z), computed stably via exp(-|yz|) only.
    yz = y * z
    enz = jnp.exp(-jnp.abs(yz))
    s = jnp.where(yz >= 0, enz / (1.0 + enz), 1.0 / (1.0 + enz))
    w = -y * s
    return (x.T @ w) / n_total


def nlls_grad_ref(x, y, theta, n_total):
    """Data-term gradient of the nonconvex NLLS loss (Eq. 23)."""
    z = x @ theta
    p = 1.0 / (1.0 + jnp.exp(-z))
    w = -(y - p) * p * (1.0 - p)
    return (x.T @ w) / n_total
