"""L1 Pallas kernel: the fused GD-SEC censor + error-correction step.

This is the per-worker hot spot that runs every round on every worker over
the full parameter vector: Δ = ∇f − h + e, component-wise threshold test
(Eq. 2 of the paper), state-variable and error-memory updates. One fused
pass → each of the 5 input streams is read once and each of the 3 outputs
written once.

TPU adaptation (DESIGN.md §Hardware-Adaptation): this is a pure VPU
elementwise kernel; we tile the parameter vector into VMEM-resident blocks
via BlockSpec. Arithmetic intensity is fixed (~7 flops per 32 bytes moved),
so the kernel is HBM-bandwidth-bound and the lowering goal is simply one
pass in, one pass out. interpret=True everywhere in this repo (the CPU
PJRT plugin cannot execute Mosaic custom-calls); the BlockSpec structure is
what a real TPU lowering would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block of 32768 = 256 sublanes x 128 lanes of f32. Perf note
# (EXPERIMENTS.md §Perf/L1): the lowered kernel walks the grid in an XLA
# while-loop; at BLOCK=1024 the 334k-param transformer sparsify paid 326
# loop steps of dynamic-slice overhead (374 ms measured via PJRT CPU).
# Sweep: 1024→374ms, 8192→52ms, 32768→24ms, 131072→17.7ms. We keep 32768:
# VMEM footprint 9 tiles x 32768 x 4 B = 1.2 MiB leaves ~6x headroom for
# double buffering on a 16 MiB-VMEM TPU core, whereas 131072 (4.7 MiB,
# 9.4 MiB double-buffered) would crowd out the compiler's prefetching.
BLOCK = 32768


def _kernel(grad_ref, h_ref, e_ref, tdiff_ref, xi_ref, scal_ref,
            wire_ref, h_new_ref, e_new_ref):
    """One VMEM-resident block of the fused censor + EC step."""
    beta = scal_ref[0]
    m_inv = scal_ref[1]
    delta = grad_ref[...] - h_ref[...] + e_ref[...]
    tau = xi_ref[...] * m_inv * jnp.abs(tdiff_ref[...])
    keep = jnp.abs(delta) > tau
    wire = jnp.where(keep, delta, 0.0)
    wire_ref[...] = wire
    h_new_ref[...] = h_ref[...] + beta * wire
    e_new_ref[...] = delta - wire


@functools.partial(jax.jit, static_argnames=("block",))
def gdsec_sparsify(grad, h, e, theta_diff, xi, scalars, *, block=BLOCK):
    """Fused GD-SEC worker step over a d-vector.

    Args:
      grad, h, e, theta_diff, xi: f32[d]
      scalars: f32[2] = [beta, 1/M]
      block: VMEM tile size (multiple of 128 on real TPU).

    Returns:
      (wire, h_new, e_new): f32[d] each. `wire` is the dense form of the
      sparsified Δ̂ (zeros where censored); the L3 coordinator RLE-encodes
      it for the uplink.
    """
    d = grad.shape[0]
    blk = min(block, _round_up(d, 128))
    dp = _round_up(d, blk)
    pad = dp - d
    if pad:
        # Zero-pad to a whole number of blocks. Padded grad=h=e=0 gives
        # delta=0 which never survives the strict '>' test, so padding is
        # inert; outputs are sliced back to d.
        z = lambda v: jnp.pad(v, (0, pad))
        grad, h, e, theta_diff, xi = map(z, (grad, h, e, theta_diff, xi))
    grid = dp // blk
    spec = pl.BlockSpec((blk,), lambda i: (i,))
    scal_spec = pl.BlockSpec((2,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((dp,), grad.dtype)] * 3
    wire, h_new, e_new = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, spec, spec, scal_spec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=True,
    )(grad, h, e, theta_diff, xi, scalars)
    if pad:
        wire, h_new, e_new = wire[:d], h_new[:d], e_new[:d]
    return wire, h_new, e_new


def _round_up(x, to):
    return ((x + to - 1) // to) * to


def vmem_bytes_per_block(block=BLOCK, dtype_bytes=4):
    """Structural VMEM footprint: 6 input + 3 output tiles resident."""
    return 9 * block * dtype_bytes


def bytes_moved_per_element(dtype_bytes=4):
    """HBM traffic per parameter: 5 vector reads + 3 vector writes."""
    return 8 * dtype_bytes
