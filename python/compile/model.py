"""L2: JAX worker-step functions and the e2e transformer LM.

Everything here is *build-time only*: `aot.py` lowers these functions once
to HLO text under `artifacts/`, and the Rust coordinator executes the
compiled artifacts via PJRT. Python never runs on the request path.

Worker-step functions fuse the shard gradient (L1 `linreg_grad` kernel for
linear regression, jnp for the other losses) with the L1 `gdsec_sparsify`
kernel, so one PJRT execution performs the complete Algorithm-1 worker
iteration.
"""

import jax
import jax.numpy as jnp

from .kernels.gdsec_sparsify import gdsec_sparsify
from .kernels.linreg_grad import linreg_grad
from .kernels import ref

# ---------------------------------------------------------------------------
# Objective gradients (Eqs. 19, 20, 23). scalars layout for worker steps:
#   scalars: f32[4] = [beta, 1/M, 1/N, lambda]
# ---------------------------------------------------------------------------


def _local_loss(kind, x, y, theta, n_inv, lam_over_m):
    z = x @ theta
    if kind == "linreg":
        data = 0.5 * n_inv * jnp.sum((y - z) ** 2)
        reg = 0.5 * lam_over_m * jnp.sum(theta**2)
    elif kind == "logreg":
        yz = y * z
        data = n_inv * jnp.sum(jnp.logaddexp(0.0, -yz))
        reg = 0.5 * lam_over_m * jnp.sum(theta**2)
    elif kind == "nlls":
        p = jax.nn.sigmoid(z)
        data = 0.5 * n_inv * jnp.sum((y - p) ** 2)
        reg = 0.5 * lam_over_m * jnp.sum(theta**2)
    else:
        raise ValueError(kind)
    return data + reg


def _local_grad(kind, x, y, theta, n_inv, lam_over_m):
    if kind == "linreg":
        # L1 Pallas kernel for the data term.
        g = linreg_grad(x, y, theta, jnp.stack([n_inv]))
    elif kind == "logreg":
        g = _logreg_grad(x, y, theta, n_inv)
    elif kind == "nlls":
        g = _nlls_grad(x, y, theta, n_inv)
    else:
        raise ValueError(kind)
    return g + lam_over_m * theta


def _logreg_grad(x, y, theta, n_inv):
    yz = y * (x @ theta)
    enz = jnp.exp(-jnp.abs(yz))
    s = jnp.where(yz >= 0, enz / (1.0 + enz), 1.0 / (1.0 + enz))
    return n_inv * ((-y * s) @ x)


def _nlls_grad(x, y, theta, n_inv):
    p = jax.nn.sigmoid(x @ theta)
    w = -(y - p) * p * (1.0 - p)
    return n_inv * (w @ x)


def make_worker_step(kind):
    """Build the fused Algorithm-1 worker iteration for one loss family.

    Signature of the returned function (all f32):
      (x[n,d], y[n], theta[d], theta_prev[d], h[d], e[d], xi[d], scalars[4])
        -> (wire[d], h_new[d], e_new[d], loss[1])

    scalars = [beta, 1/M, 1/N, lambda]. `wire` is the dense Δ̂ (zeros where
    censored); L3 RLE-encodes it.
    """

    def step(x, y, theta, theta_prev, h, e, xi, scalars):
        beta, m_inv, n_inv, lam = scalars[0], scalars[1], scalars[2], scalars[3]
        lam_over_m = lam * m_inv
        grad = _local_grad(kind, x, y, theta, n_inv, lam_over_m)
        loss = _local_loss(kind, x, y, theta, n_inv, lam_over_m)
        wire, h_new, e_new = gdsec_sparsify(
            grad, h, e, theta - theta_prev, xi, jnp.stack([beta, m_inv])
        )
        return wire, h_new, e_new, jnp.reshape(loss, (1,))

    step.__name__ = f"worker_step_{kind}"
    return step


# ---------------------------------------------------------------------------
# Tiny transformer LM for the end-to-end example.
#
# Decoder-only, learned positions, pre-LN blocks. Parameters travel as ONE
# flat f32 vector so the GD-SEC machinery (built around R^d) applies
# unchanged; (un)flattening layout is fixed by `param_specs`.
# ---------------------------------------------------------------------------


class TfmConfig:
    def __init__(self, vocab=256, seq=32, d_model=128, n_layers=2, n_heads=4, d_ff=256):
        self.vocab = vocab
        self.seq = seq
        self.d_model = d_model
        self.n_layers = n_layers
        self.n_heads = n_heads
        self.d_ff = d_ff

    def param_specs(self):
        """Ordered (name, shape) list defining the flat layout."""
        c = self
        specs = [
            ("tok_embed", (c.vocab, c.d_model)),
            ("pos_embed", (c.seq, c.d_model)),
        ]
        for l in range(c.n_layers):
            specs += [
                (f"l{l}.ln1.g", (c.d_model,)),
                (f"l{l}.ln1.b", (c.d_model,)),
                (f"l{l}.attn.wqkv", (c.d_model, 3 * c.d_model)),
                (f"l{l}.attn.wo", (c.d_model, c.d_model)),
                (f"l{l}.ln2.g", (c.d_model,)),
                (f"l{l}.ln2.b", (c.d_model,)),
                (f"l{l}.mlp.w1", (c.d_model, c.d_ff)),
                (f"l{l}.mlp.b1", (c.d_ff,)),
                (f"l{l}.mlp.w2", (c.d_ff, c.d_model)),
                (f"l{l}.mlp.b2", (c.d_model,)),
            ]
        specs += [
            ("ln_f.g", (c.d_model,)),
            ("ln_f.b", (c.d_model,)),
            ("head", (c.d_model, c.vocab)),
        ]
        return specs

    def n_params(self):
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_specs())


def unflatten(cfg, flat):
    params = {}
    off = 0
    for name, shape in cfg.param_specs():
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def init_params(cfg, key):
    """Standard small-transformer init, returned flat."""
    parts = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".b1", ".b2", "ln1.b", "ln2.b", "ln_f.b")):
            parts.append(jnp.zeros(shape, jnp.float32).ravel())
        elif "ln" in name and name.endswith(".g"):
            parts.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 0.02 if "embed" in name else 1.0 / jnp.sqrt(fan_in)
            parts.append((jax.random.normal(sub, shape) * scale).astype(jnp.float32).ravel())
    return jnp.concatenate(parts)


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attention(cfg, x, wqkv, wo):
    b, t, dm = x.shape
    nh = cfg.n_heads
    hd = dm // nh
    qkv = x @ wqkv  # [b, t, 3*dm]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, nh, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, dm)
    return out @ wo


def forward(cfg, flat_params, tokens):
    """Logits for next-token prediction. tokens: i32[b, t]."""
    p = unflatten(cfg, flat_params)
    x = p["tok_embed"][tokens] + p["pos_embed"][None, : tokens.shape[1]]
    for l in range(cfg.n_layers):
        ln1 = _layernorm(x, p[f"l{l}.ln1.g"], p[f"l{l}.ln1.b"])
        x = x + _attention(cfg, ln1, p[f"l{l}.attn.wqkv"], p[f"l{l}.attn.wo"])
        ln2 = _layernorm(x, p[f"l{l}.ln2.g"], p[f"l{l}.ln2.b"])
        hdn = jax.nn.gelu(ln2 @ p[f"l{l}.mlp.w1"] + p[f"l{l}.mlp.b1"])
        x = x + hdn @ p[f"l{l}.mlp.w2"] + p[f"l{l}.mlp.b2"]
    x = _layernorm(x, p["ln_f.g"], p["ln_f.b"])
    return x @ p["head"]


def lm_loss(cfg, flat_params, tokens):
    """Mean next-token cross-entropy over positions 0..t-2."""
    logits = forward(cfg, flat_params, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def make_tfm_loss_grad(cfg):
    """(params_flat[d], tokens[b,t]) -> (loss[1], grad[d])."""

    def loss_grad(flat_params, tokens):
        loss, grad = jax.value_and_grad(lambda q: lm_loss(cfg, q, tokens))(flat_params)
        return jnp.reshape(loss, (1,)), grad

    return loss_grad
